//! Leader: the end-to-end pipeline of Alg. 1, split into explicit stages
//! over a pluggable evaluation backend.
//!
//!   1. [`Leader::pretrain`] — FP16 pretraining (bits=16, widths=1.0) plus
//!      the FiP16 baseline metrics,
//!   2. [`Leader::prune`] — Hutchinson Hessian traces + §III-A space prune,
//!   3. [`Leader::search`] — the configured searcher over the pruned joint
//!      space, evaluated either in-process ([`EvalBackend::InProcess`]) or
//!      across a worker pool ([`EvalBackend::Remote`]) whose session
//!      handshake ships the pruned space, objective knobs, hardware model,
//!      and pretrained-snapshot digest — and whose workers answer with full
//!      `EvalRecord`s, so the report is identical either way,
//!   4. [`Leader::finalize`] — final training of the winner + SearchReport.
//!
//! With [`SessionOpts::checkpoint`] the search stage writes a
//! [`SessionCheckpoint`] after every round; [`SessionOpts::resume`]
//! warm-starts the surrogates, history, records, and RNG cursor from one, so
//! a killed search (local or distributed) continues instead of restarting
//! cold — which also covers cross-run warm-starting onto a tighter budget.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::baselines::{Evolutionary, EvolutionaryParams, GpBo, GpBoParams, RandomSearch,
                       Reinforce, ReinforceParams};
use crate::coordinator::evaluator::{build_space, DnnObjective, EvalRecord, ObjectiveCfg,
                                    SpaceBuild};
use crate::coordinator::service::{PoolCfg, RemoteObjective, SessionSpec};
use crate::hessian::pruner::{prune_space, PrunedSpace};
use crate::hw::HwConfig;
use crate::search::{BatchAlgo, BatchSearcher, History, KmeansTpe, KmeansTpeParams, Objective,
                    QPolicy, SearchCheckpoint, Searcher, Tpe, TpeParams};
use crate::train::session::{ModelSession, ParamSnapshot};
use crate::util::json::{obj, Json};
use crate::util::Timer;

#[derive(Debug, Clone, Copy)]
pub struct LeaderCfg {
    pub seed: u64,
    /// FP pretraining steps (the "pretrained model" the paper starts from).
    pub pretrain_steps: usize,
    pub pretrain_lr: f64,
    /// Hutchinson samples for trace estimation.
    pub hessian_samples: usize,
    /// k for the §III-A sensitivity clustering.
    pub sensitivity_clusters: usize,
    /// Search budget n and startup n0 (Alg. 1).
    pub n_evals: usize,
    pub n_startup: usize,
    /// Final-training steps for the winning config.
    pub final_steps: usize,
    pub final_lr: f64,
    pub objective: ObjectiveCfg,
    /// Skip Hessian pruning (ablation).
    pub prune: bool,
    /// Proposals per search round (q), as parsed from `--batch-q <q>|auto`.
    /// `Fixed(1)` = classic sequential loop; `Fixed(q > 1)` switches the
    /// TPE-family searchers to constant-liar batched rounds; `Auto` tunes q
    /// online between 1 and the objective's parallelism from the observed
    /// eval/proposal cost ratio. Rounds only pay off when the objective's
    /// `eval_batch` is actually parallel (`RemoteObjective`,
    /// `ParallelObjective`); the in-process `DnnObjective` the leader
    /// drives evaluates a round sequentially, so fixed q > 1 there trades
    /// surrogate freshness for no wall-clock gain — and `Auto` correctly
    /// collapses to q = 1 on it.
    pub batch_q: QPolicy,
}

impl Default for LeaderCfg {
    fn default() -> Self {
        LeaderCfg {
            seed: 0,
            pretrain_steps: 150,
            pretrain_lr: 3e-3,
            hessian_samples: 4,
            sensitivity_clusters: 4,
            n_evals: 40,
            n_startup: 10,
            final_steps: 300,
            final_lr: 3e-3,
            objective: ObjectiveCfg::default(),
            prune: true,
            batch_q: QPolicy::Fixed(1),
        }
    }
}

/// Which search algorithm the leader drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    KmeansTpe,
    Tpe,
    Random,
    Evolutionary,
    Reinforce,
    GpBo,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        match s {
            "kmeans-tpe" | "kmeans_tpe" | "ours" => Some(Algo::KmeansTpe),
            "tpe" => Some(Algo::Tpe),
            "random" => Some(Algo::Random),
            "evolutionary" | "evo" => Some(Algo::Evolutionary),
            "reinforce" | "rl" => Some(Algo::Reinforce),
            "gp-bo" | "gp_bo" | "bomp" => Some(Algo::GpBo),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::KmeansTpe => "kmeans-tpe",
            Algo::Tpe => "tpe",
            Algo::Random => "random",
            Algo::Evolutionary => "evolutionary",
            Algo::Reinforce => "reinforce",
            Algo::GpBo => "gp-bo",
        }
    }
}

/// Where the search stage's evaluations run.
#[derive(Debug, Clone, Default)]
pub enum EvalBackend {
    /// The leader's own `DnnObjective` (sequential proxy-QAT).
    #[default]
    InProcess,
    /// A `sammpq worker` pool: the session handshake syncs the pruned
    /// space + objective + hardware model + snapshot digest, and every
    /// trial's `EvalRecord` comes back over the wire.
    Remote { addrs: Vec<String>, pool: PoolCfg },
}

/// Per-run session options (backend + checkpoint/resume paths).
#[derive(Debug, Clone, Default)]
pub struct SessionOpts {
    pub backend: EvalBackend,
    /// Write a [`SessionCheckpoint`] after every search round: a single
    /// atomically-rewritten file, or — with [`checkpoint_keep`] set — a
    /// ROTATION DIRECTORY of per-round checkpoints plus a `manifest.json`
    /// naming the newest (crash forensics; see [`CheckpointStore`]).
    ///
    /// [`checkpoint_keep`]: Self::checkpoint_keep
    pub checkpoint: Option<PathBuf>,
    /// `--checkpoint-keep N`: treat [`checkpoint`](Self::checkpoint) as a
    /// directory, keep the N newest per-round checkpoints, GC the rest.
    pub checkpoint_keep: Option<usize>,
    /// Warm-start the search from this checkpoint — a file, or a rotation
    /// directory (the manifest picks the newest valid one automatically).
    pub resume: Option<PathBuf>,
    /// Leave the worker processes serving after the search (`bye` the
    /// session instead of shutting the farm down) — the multi-tenant
    /// deployment mode, where one farm backs many leaders.
    pub keep_workers: bool,
}

/// An objective whose evaluations produce full [`EvalRecord`]s, in eval
/// order — what the search stage needs to assemble a report and write
/// session checkpoints regardless of backend.
pub trait RecordedObjective: Objective {
    fn records(&self) -> &[EvalRecord];
}

impl RecordedObjective for DnnObjective<'_> {
    fn records(&self) -> &[EvalRecord] {
        &self.log
    }
}

impl RecordedObjective for RemoteObjective {
    fn records(&self) -> &[EvalRecord] {
        &self.log
    }
}

pub const CHECKPOINT_VERSION: u64 = 1;

/// A search session frozen at a round boundary: the searcher state (history
/// + surrogate cursors + RNG) plus the full record log and enough leader
/// metadata to refuse a mismatched resume.
#[derive(Debug, Clone)]
pub struct SessionCheckpoint {
    pub algo: String,
    pub seed: u64,
    pub n_evals: usize,
    pub search: SearchCheckpoint,
    pub records: Vec<EvalRecord>,
}

impl SessionCheckpoint {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("version", Json::Num(CHECKPOINT_VERSION as f64)),
            ("algo", Json::Str(self.algo.clone())),
            ("seed", Json::Str(format!("{:016x}", self.seed))),
            ("n_evals", Json::Num(self.n_evals as f64)),
            ("search", self.search.to_json()),
            ("records", Json::Arr(self.records.iter().map(|r| r.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SessionCheckpoint> {
        let version = j.req("version")?.as_usize().context("version")?;
        anyhow::ensure!(
            version as u64 == CHECKPOINT_VERSION,
            "checkpoint version {version} (this build writes {CHECKPOINT_VERSION})"
        );
        let seed_hex = j.req("seed")?.as_str().context("seed")?;
        let ck = SessionCheckpoint {
            algo: j.req("algo")?.as_str().context("algo")?.to_string(),
            seed: u64::from_str_radix(seed_hex, 16)
                .with_context(|| format!("bad seed '{seed_hex}'"))?,
            n_evals: j.req("n_evals")?.as_usize().context("n_evals")?,
            search: SearchCheckpoint::from_json(j.req("search")?)?,
            records: j
                .req("records")?
                .as_arr()
                .context("records")?
                .iter()
                .map(EvalRecord::from_json)
                .collect::<Result<_>>()?,
        };
        anyhow::ensure!(
            ck.records.len() == ck.search.history.len(),
            "checkpoint has {} records for {} trials",
            ck.records.len(),
            ck.search.history.len()
        );
        Ok(ck)
    }

    /// Atomic write (temp file + rename): a crash mid-write must never
    /// leave a torn checkpoint where a valid one stood.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json().to_string_pretty() + "\n")?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("commit checkpoint {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<SessionCheckpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read checkpoint {}", path.display()))?;
        let j = Json::parse(text.trim())
            .map_err(|e| anyhow::anyhow!("parse checkpoint {}: {e}", path.display()))?;
        SessionCheckpoint::from_json(&j)
    }

    /// `--resume` accepts either a single checkpoint file or a rotation
    /// directory — a directory resolves through its manifest to the newest
    /// VALID checkpoint ([`CheckpointStore::load_latest`]).
    pub fn load_auto(path: &Path) -> Result<SessionCheckpoint> {
        if path.is_dir() {
            CheckpointStore::load_latest(path)
        } else {
            SessionCheckpoint::load(path)
        }
    }
}

/// File name of a rotation directory's manifest.
pub const MANIFEST_NAME: &str = "manifest.json";

/// Rotated per-round session checkpoints (`--checkpoint <dir>
/// --checkpoint-keep N`): every round writes a fresh `ckpt-<trials>.json`
/// instead of rewriting one file, a `manifest.json` names the newest valid
/// one, and files beyond the newest N are garbage-collected. Rotation buys
/// crash forensics (the last rounds before a failure stay inspectable) and
/// a fallback chain: if the newest file is torn — the crash landed
/// mid-rotation — resume walks back to the one before it.
pub struct CheckpointStore {
    dir: PathBuf,
    keep: usize,
}

impl CheckpointStore {
    /// Store over `dir`, keeping the `keep.max(1)` newest checkpoints.
    pub fn new(dir: PathBuf, keep: usize) -> CheckpointStore {
        CheckpointStore { dir, keep: keep.max(1) }
    }

    /// Zero-padded so lexicographic order == trial order.
    fn file_name(trials: usize) -> String {
        format!("ckpt-{trials:08}.json")
    }

    /// Rotated checkpoint file names in `dir`, ascending by trial count.
    fn rotated(dir: &Path) -> Result<Vec<String>> {
        let mut names: Vec<String> = std::fs::read_dir(dir)
            .with_context(|| format!("list checkpoint dir {}", dir.display()))?
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with("ckpt-") && n.ends_with(".json"))
            .collect();
        names.sort();
        Ok(names)
    }

    /// Write `ck` as a fresh rotated file, GC rotated files beyond `keep`
    /// (oldest first, never the file just written), then repoint the
    /// manifest. Ordering matters twice over: the manifest must never
    /// name a file that is not yet durable (checkpoint first) and its
    /// `kept` list must only name files that survive (GC before
    /// manifest). A crash in the window after GC but before the manifest
    /// rename can leave the manifest pointing at a deleted PREVIOUS
    /// latest — `load_latest`'s newest-first scan fallback heals exactly
    /// that. Returns the checkpoint's path.
    pub fn save(&self, ck: &SessionCheckpoint) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)?;
        let name = CheckpointStore::file_name(ck.search.history.len());
        let path = self.dir.join(&name);
        ck.save(&path)?;
        let rotated = CheckpointStore::rotated(&self.dir)?;
        if rotated.len() > self.keep {
            for stale in &rotated[..rotated.len() - self.keep] {
                if stale != &name {
                    let _ = std::fs::remove_file(self.dir.join(stale));
                }
            }
        }
        let kept = CheckpointStore::rotated(&self.dir)?;
        let manifest = obj(vec![
            ("version", Json::Num(CHECKPOINT_VERSION as f64)),
            ("latest", Json::Str(name.clone())),
            ("kept", Json::Arr(kept.iter().map(|n| Json::Str(n.clone())).collect())),
        ]);
        let tmp = self.dir.join("manifest.tmp");
        std::fs::write(&tmp, manifest.to_string_pretty() + "\n")?;
        std::fs::rename(&tmp, self.dir.join(MANIFEST_NAME))
            .with_context(|| format!("commit manifest in {}", self.dir.display()))?;
        Ok(path)
    }

    /// Newest VALID checkpoint under `dir`: the manifest's `latest` when
    /// it loads, else a newest-first scan over the rotated files (a torn
    /// newest file falls back to the round before it).
    pub fn load_latest(dir: &Path) -> Result<SessionCheckpoint> {
        if let Ok(text) = std::fs::read_to_string(dir.join(MANIFEST_NAME)) {
            if let Ok(m) = Json::parse(text.trim()) {
                if let Some(latest) = m.get("latest").and_then(|v| v.as_str()) {
                    match SessionCheckpoint::load(&dir.join(latest)) {
                        Ok(ck) => return Ok(ck),
                        Err(e) => eprintln!(
                            "[resume] manifest names '{latest}' but it fails to load \
                             ({e:#}); scanning older checkpoints"
                        ),
                    }
                }
            }
        }
        let mut names = CheckpointStore::rotated(dir)?;
        names.reverse();
        for name in &names {
            match SessionCheckpoint::load(&dir.join(name)) {
                Ok(ck) => return Ok(ck),
                Err(e) => eprintln!("[resume] skipping invalid checkpoint '{name}': {e:#}"),
            }
        }
        anyhow::bail!("no valid checkpoint under {}", dir.display())
    }
}

/// Everything the experiment drivers need.
pub struct SearchReport {
    pub tag: String,
    pub algo: &'static str,
    pub history: History,
    pub records: Vec<EvalRecord>,
    pub pruned: Option<PrunedSpace>,
    pub build: SpaceBuild,
    /// Best record by composite objective.
    pub best: EvalRecord,
    /// Best config retrained for final_steps: (accuracy, size, latency, speedup).
    pub final_accuracy: f64,
    pub final_size_mb: f64,
    pub final_latency_ms: f64,
    pub final_speedup: f64,
    /// FiP16 baseline accuracy + size (trained for the same final budget).
    pub baseline_accuracy: f64,
    pub baseline_size_mb: f64,
    /// Wall-clock costs (the Table III search-cost column).
    pub pretrain_secs: f64,
    pub search_secs: f64,
    pub final_secs: f64,
}

/// Build the searcher a `LeaderCfg` asks for. Separated from [`Leader`]
/// (which needs a live `ModelSession`) so the `batch_q` -> searcher
/// plumbing is testable without PJRT artifacts.
fn searcher_for(cfg: &LeaderCfg, algo: Algo) -> Box<dyn Searcher> {
    let seed = cfg.seed;
    let n0 = cfg.n_startup;
    if cfg.batch_q.batched() {
        // Batched rounds exist for the model-based TPE family; the other
        // baselines keep their published sequential loops.
        let policy = cfg.batch_q;
        match algo {
            Algo::KmeansTpe => {
                return Box::new(BatchSearcher::new(
                    crate::search::BatchAlgo::KmeansTpe(KmeansTpeParams {
                        n_startup: n0,
                        seed,
                        ..Default::default()
                    }),
                    policy,
                ));
            }
            Algo::Tpe => {
                return Box::new(BatchSearcher::new(
                    crate::search::BatchAlgo::Tpe(TpeParams {
                        n_startup: n0,
                        seed,
                        ..Default::default()
                    }),
                    policy,
                ));
            }
            _ => {}
        }
    }
    match algo {
        Algo::KmeansTpe => Box::new(KmeansTpe::new(KmeansTpeParams {
            n_startup: n0,
            seed,
            ..Default::default()
        })),
        Algo::Tpe => {
            Box::new(Tpe::new(TpeParams { n_startup: n0, seed, ..Default::default() }))
        }
        Algo::Random => Box::new(RandomSearch::new(seed)),
        Algo::Evolutionary => Box::new(Evolutionary::new(EvolutionaryParams {
            seed,
            ..Default::default()
        })),
        Algo::Reinforce => {
            Box::new(Reinforce::new(ReinforceParams { seed, ..Default::default() }))
        }
        Algo::GpBo => Box::new(GpBo::new(GpBoParams {
            n_startup: n0,
            seed,
            ..Default::default()
        })),
    }
}

/// Stage-1 output: the shared pretrained snapshot + FiP16 baseline metrics.
pub struct Pretrained {
    pub snapshot: ParamSnapshot,
    pub baseline_accuracy: f64,
    pub baseline_size_mb: f64,
    pub pretrain_secs: f64,
}

/// Stage-3 output: everything the search produced.
pub struct SearchOutcome {
    pub build: SpaceBuild,
    pub history: History,
    pub records: Vec<EvalRecord>,
    pub search_secs: f64,
}

pub struct Leader<'a> {
    pub session: &'a ModelSession,
    pub cfg: LeaderCfg,
    pub hw: HwConfig,
}

impl<'a> Leader<'a> {
    pub fn new(session: &'a ModelSession, cfg: LeaderCfg, hw: HwConfig) -> Leader<'a> {
        Leader { session, cfg, hw }
    }

    fn make_searcher(&self, algo: Algo) -> Box<dyn Searcher> {
        searcher_for(&self.cfg, algo)
    }

    /// Run the full pipeline in-process (the classic single-machine path).
    pub fn run(&self, algo: Algo) -> Result<SearchReport> {
        self.run_session(algo, &SessionOpts::default())
    }

    /// Run the full pipeline: pretrain -> prune -> search -> finalize, over
    /// whichever backend and checkpoint policy `opts` selects.
    pub fn run_session(&self, algo: Algo, opts: &SessionOpts) -> Result<SearchReport> {
        let pre = self.pretrain()?;
        let pruned = self.prune(&pre)?;
        let search = self.search(algo, &pre, pruned.as_ref(), opts)?;
        self.finalize(algo, pre, pruned, search)
    }

    /// Stage 1: FP16 pretraining, plus the FiP16 baseline continued to the
    /// final budget (the comparison column of the tables).
    pub fn pretrain(&self) -> Result<Pretrained> {
        let sess = self.session;
        let meta = &sess.meta;
        let cfg = &self.cfg;
        let t_pre = Timer::start();
        let snap0 = sess.init_snapshot(cfg.seed);
        let mut state = sess.state_from_snapshot(&snap0)?;
        let bits16 = meta.uniform_bits(16.0);
        let widths1 = meta.base_widths();
        sess.train(&mut state, &bits16, &widths1, cfg.pretrain_steps, cfg.pretrain_lr)?;
        let snapshot = sess.snapshot_of(&state)?;
        let pretrain_secs = t_pre.secs();

        let mut base_state = sess.state_from_snapshot(&snapshot)?;
        sess.train(&mut base_state, &bits16, &widths1, cfg.final_steps, cfg.final_lr)?;
        let baseline_accuracy = sess.evaluate(
            &base_state,
            &bits16,
            &widths1,
            cfg.objective.eval_batches.max(8),
        )?;
        let (b16, w10) = meta.resolve(|_| 16.0, |_| 1.0);
        let baseline_size_mb = meta.net_shape(&b16, &w10).model_size_mb();
        Ok(Pretrained { snapshot, baseline_accuracy, baseline_size_mb, pretrain_secs })
    }

    /// Stage 2: Hutchinson sensitivity analysis + §III-A space pruning
    /// (`None` when pruning is disabled for an ablation).
    pub fn prune(&self, pre: &Pretrained) -> Result<Option<PrunedSpace>> {
        if !self.cfg.prune {
            return Ok(None);
        }
        let sess = self.session;
        let meta = &sess.meta;
        let state = sess.state_from_snapshot(&pre.snapshot)?;
        let bits16 = meta.uniform_bits(16.0);
        let widths1 = meta.base_widths();
        let traces = sess.hessian_traces(&state, &widths1, self.cfg.hessian_samples)?;
        // Weight counts per layer from the hw shape at base width.
        let net = meta.net_shape(&bits16, &widths1);
        let counts: Vec<usize> = net.layers.iter().map(|l| l.weights() as usize).collect();
        Ok(Some(prune_space(&traces, &counts, self.cfg.sensitivity_clusters)))
    }

    /// Stage 3: run the searcher over the pruned space, through the chosen
    /// evaluation backend. In remote mode every worker is space-synced (and
    /// digest-checked) before the first config ships, and the record log is
    /// assembled from the workers' `EvalRecord` replies.
    pub fn search(
        &self,
        algo: Algo,
        pre: &Pretrained,
        pruned: Option<&PrunedSpace>,
        opts: &SessionOpts,
    ) -> Result<SearchOutcome> {
        let sess = self.session;
        let build = build_space(&sess.meta, pruned);
        let t_search = Timer::start();
        let (history, records) = match &opts.backend {
            EvalBackend::InProcess => {
                let mut objective = DnnObjective::new(
                    sess,
                    pre.snapshot.clone(),
                    build.clone(),
                    self.hw,
                    self.cfg.objective,
                );
                self.drive(algo, &mut objective, opts)?
            }
            EvalBackend::Remote { addrs, pool } => {
                let spec = SessionSpec {
                    build: build.clone(),
                    objective: self.cfg.objective,
                    hw: self.hw,
                    digest: pre.snapshot.digest(),
                };
                let mut objective = RemoteObjective::connect_session(spec, addrs, *pool)?;
                let out = self.drive(algo, &mut objective, opts);
                // Best-effort either way (workers outlive a failed search
                // for the next session): on a shared farm, `bye` only this
                // session and leave the processes serving other tenants;
                // otherwise shut the farm down with the search.
                if opts.keep_workers {
                    let _ = objective.release();
                } else {
                    let _ = objective.shutdown();
                }
                out?
            }
        };
        Ok(SearchOutcome { build, history, records, search_secs: t_search.secs() })
    }

    /// Search-loop driver shared by both backends. Without checkpointing
    /// this is a plain `Searcher::run`; with `--checkpoint`/`--resume` the
    /// TPE-family searcher runs STEPWISE, so the session (history, records,
    /// surrogate cursors, RNG) is frozen at every round boundary and a
    /// killed search resumes instead of restarting cold.
    fn drive<O: RecordedObjective>(
        &self,
        algo: Algo,
        objective: &mut O,
        opts: &SessionOpts,
    ) -> Result<(History, Vec<EvalRecord>)> {
        let budget = self.cfg.n_evals;
        if opts.checkpoint.is_none() && opts.resume.is_none() {
            let mut searcher = self.make_searcher(algo);
            let history = searcher.run(objective, budget);
            let records = objective.records().to_vec();
            return Ok((history, records));
        }

        let batch_algo = match algo {
            Algo::KmeansTpe => BatchAlgo::KmeansTpe(KmeansTpeParams {
                n_startup: self.cfg.n_startup,
                seed: self.cfg.seed,
                ..Default::default()
            }),
            Algo::Tpe => BatchAlgo::Tpe(TpeParams {
                n_startup: self.cfg.n_startup,
                seed: self.cfg.seed,
                ..Default::default()
            }),
            other => anyhow::bail!(
                "--checkpoint/--resume need a TPE-family --algo (kmeans-tpe or tpe), \
                 got '{}'",
                other.name()
            ),
        };
        let searcher = BatchSearcher::new(batch_algo, self.cfg.batch_q);
        let resumed = opts.resume.as_deref().map(SessionCheckpoint::load_auto).transpose()?;
        let mut prior: Vec<EvalRecord> = Vec::new();
        if let Some(ck) = &resumed {
            anyhow::ensure!(
                ck.algo == algo.name(),
                "checkpoint holds a '{}' search, this run is '{}'",
                ck.algo,
                algo.name()
            );
            anyhow::ensure!(
                ck.seed == self.cfg.seed,
                "checkpoint seed {:#x} != --seed {:#x}: resuming would splice two \
                 different random streams",
                ck.seed,
                self.cfg.seed
            );
            prior = ck.records.clone();
        }
        let mut run = searcher.start(
            objective.space().clone(),
            budget,
            resumed.as_ref().map(|c| &c.search),
        )?;
        let store = match (&opts.checkpoint, opts.checkpoint_keep) {
            (Some(dir), Some(keep)) => Some(CheckpointStore::new(dir.clone(), keep)),
            _ => None,
        };
        while !run.done() {
            run.step(objective);
            if let Some(path) = &opts.checkpoint {
                let mut records = prior.clone();
                records.extend(objective.records().iter().cloned());
                let ck = SessionCheckpoint {
                    algo: algo.name().to_string(),
                    seed: self.cfg.seed,
                    n_evals: budget,
                    search: run.checkpoint(),
                    records,
                };
                match &store {
                    Some(store) => {
                        store.save(&ck)?;
                    }
                    None => ck.save(path)?,
                }
            }
        }
        let (history, _rounds) = run.finish();
        let mut records = prior;
        records.extend(objective.records().iter().cloned());
        Ok((history, records))
    }

    /// Stage 4: final training of the winner + report assembly. Works from
    /// records alone, so it is backend-agnostic — remote searches finalize
    /// exactly like in-process ones.
    pub fn finalize(
        &self,
        algo: Algo,
        pre: Pretrained,
        pruned: Option<PrunedSpace>,
        search: SearchOutcome,
    ) -> Result<SearchReport> {
        let sess = self.session;
        let cfg = &self.cfg;
        let SearchOutcome { build, history, records, search_secs } = search;
        let best_trial = history.best().expect("non-empty history");
        let best = records
            .iter()
            .find(|r| r.config == best_trial.config)
            .expect("best record")
            .clone();

        let t_final = Timer::start();
        let (bits, widths) = build.decode(&sess.meta, &best.config);
        let mut final_state = sess.state_from_snapshot(&pre.snapshot)?;
        sess.train(&mut final_state, &bits, &widths, cfg.final_steps, cfg.final_lr)?;
        let final_accuracy = sess.evaluate(
            &final_state,
            &bits,
            &widths,
            cfg.objective.eval_batches.max(8),
        )?;
        let final_secs = t_final.secs();
        // Hardware metrics are analytic (no training, no snapshot) —
        // computed leader-side for every backend, same formulas as
        // `DnnObjective::hw_metrics`.
        let meta = &sess.meta;
        let net = meta.net_shape(&bits, &widths);
        let final_size_mb = net.model_size_mb();
        let cycles = crate::hw::latency_cycles(&self.hw, &net);
        let final_latency_ms = self.hw.cycles_to_ms(cycles);
        let (b16, w10) = meta.resolve(|_| 16.0, |_| 1.0);
        let final_speedup =
            crate::hw::baseline_latency_cycles(&self.hw, &meta.net_shape(&b16, &w10)) / cycles;

        Ok(SearchReport {
            tag: sess.tag.clone(),
            algo: algo.name(),
            history,
            records,
            pruned,
            build,
            best,
            final_accuracy,
            final_size_mb,
            final_latency_ms,
            final_speedup,
            baseline_accuracy: pre.baseline_accuracy,
            baseline_size_mb: pre.baseline_size_mb,
            pretrain_secs: pre.pretrain_secs,
            search_secs,
            final_secs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_q_parses_fixed_and_auto() {
        assert_eq!(QPolicy::parse("auto"), Some(QPolicy::Auto));
        assert_eq!(QPolicy::parse("AUTO"), Some(QPolicy::Auto));
        assert_eq!(QPolicy::parse("4"), Some(QPolicy::Fixed(4)));
        // 0 is clamped to the sequential loop, garbage is rejected.
        assert_eq!(QPolicy::parse("0"), Some(QPolicy::Fixed(1)));
        assert_eq!(QPolicy::parse("q"), None);
        assert!(!QPolicy::Fixed(1).batched());
        assert!(QPolicy::Fixed(2).batched());
        assert!(QPolicy::Auto.batched());
    }

    #[test]
    fn session_checkpoint_serde_and_atomic_save_load() {
        use crate::search::{RngState, SearchCheckpoint};
        use crate::util::rng::Rng;
        let mut history = History::new("batch-kmeans-tpe");
        history.push(vec![0, 1], 0.5, 0.1);
        history.push(vec![1, 0], f64::NEG_INFINITY, 0.2);
        let ck = SessionCheckpoint {
            algo: "kmeans-tpe".to_string(),
            // A seed above 2^53 would corrupt through a JSON number — the
            // hex encoding must carry it exactly.
            seed: 0xDEAD_BEEF_CAFE_F00D,
            n_evals: 40,
            search: SearchCheckpoint {
                algo: "batch-kmeans-tpe".to_string(),
                dims: 2,
                history,
                iter: 3,
                centroids: vec![0.5, -1.0],
                rng: RngState::of(&Rng::new(7)),
            },
            records: vec![
                EvalRecord::value_only(vec![0, 1], 0.5),
                EvalRecord::value_only(vec![1, 0], f64::NEG_INFINITY),
            ],
        };
        let text = ck.to_json().to_string_pretty();
        let back = SessionCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string_pretty(), text);
        assert_eq!(back.seed, 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(back.records.len(), 2);

        let path = std::env::temp_dir().join("sammpq_ckpt_test.json");
        ck.save(&path).unwrap();
        let loaded = SessionCheckpoint::load(&path).unwrap();
        assert_eq!(loaded.to_json().to_string_pretty(), text);
        // No torn temp file left behind.
        assert!(!path.with_extension("tmp").exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn session_checkpoint_rejects_record_history_skew() {
        use crate::search::{RngState, SearchCheckpoint};
        use crate::util::rng::Rng;
        let mut history = History::new("batch-tpe");
        history.push(vec![0], 1.0, 0.0);
        let ck = SessionCheckpoint {
            algo: "tpe".to_string(),
            seed: 1,
            n_evals: 8,
            search: SearchCheckpoint {
                algo: "batch-tpe".to_string(),
                dims: 1,
                history,
                iter: 0,
                centroids: Vec::new(),
                rng: RngState::of(&Rng::new(1)),
            },
            records: Vec::new(), // one trial, zero records
        };
        let err =
            SessionCheckpoint::from_json(&Json::parse(&ck.to_json().to_string_compact()).unwrap())
                .unwrap_err();
        assert!(err.to_string().contains("records"), "{err}");
    }

    fn ck_with_trials(n: usize) -> SessionCheckpoint {
        use crate::search::{RngState, SearchCheckpoint};
        use crate::util::rng::Rng;
        let mut history = History::new("batch-tpe");
        let mut records = Vec::new();
        for i in 0..n {
            history.push(vec![i % 3, 0], i as f64, 0.0);
            records.push(EvalRecord::value_only(vec![i % 3, 0], i as f64));
        }
        SessionCheckpoint {
            algo: "tpe".to_string(),
            seed: 7,
            n_evals: 40,
            search: SearchCheckpoint {
                algo: "batch-tpe".to_string(),
                dims: 2,
                history,
                iter: 0,
                centroids: Vec::new(),
                rng: RngState::of(&Rng::new(3)),
            },
            records,
        }
    }

    #[test]
    fn checkpoint_rotation_gc_manifest_and_torn_file_fallback() {
        use crate::coordinator::leader::MANIFEST_NAME;
        let dir = std::env::temp_dir().join(format!("sammpq_rot_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = CheckpointStore::new(dir.clone(), 2);
        for n in [3usize, 6, 9] {
            store.save(&ck_with_trials(n)).unwrap();
        }
        // GC kept exactly the 2 newest rotated files.
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with("ckpt-"))
            .collect();
        names.sort();
        assert_eq!(names, vec!["ckpt-00000006.json", "ckpt-00000009.json"]);
        // The manifest names the newest, and its kept list matches the
        // post-GC disk contents exactly (no dangling names).
        let manifest =
            Json::parse(&std::fs::read_to_string(dir.join(MANIFEST_NAME)).unwrap()).unwrap();
        assert_eq!(
            manifest.get("latest").and_then(|v| v.as_str()),
            Some("ckpt-00000009.json")
        );
        let kept: Vec<&str> = manifest
            .get("kept")
            .and_then(|k| k.as_arr())
            .unwrap()
            .iter()
            .filter_map(|v| v.as_str())
            .collect();
        assert_eq!(kept, names.iter().map(String::as_str).collect::<Vec<_>>());
        assert_eq!(SessionCheckpoint::load_auto(&dir).unwrap().search.history.len(), 9);
        // A torn newest file (crash mid-rotation) falls back to the round
        // before it — "newest VALID", not "newest named".
        std::fs::write(dir.join("ckpt-00000009.json"), "{torn").unwrap();
        assert_eq!(CheckpointStore::load_latest(&dir).unwrap().search.history.len(), 6);
        // A plain file path still resumes directly (no directory needed).
        let single = dir.join("single.json");
        ck_with_trials(4).save(&single).unwrap();
        assert_eq!(
            SessionCheckpoint::load_auto(&single).unwrap().search.history.len(),
            4
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batch_q_reaches_the_searcher() {
        // The --batch-q plumbing must actually change which searcher the
        // leader runs: fixed q > 1 and auto select the batched TPE family,
        // q = 1 keeps the sequential loops, baselines are never batched.
        let mut cfg = LeaderCfg::default();
        assert_eq!(searcher_for(&cfg, Algo::KmeansTpe).name(), "kmeans-tpe");
        assert_eq!(searcher_for(&cfg, Algo::Tpe).name(), "tpe");
        cfg.batch_q = QPolicy::Fixed(4);
        assert_eq!(searcher_for(&cfg, Algo::KmeansTpe).name(), "batch-kmeans-tpe");
        assert_eq!(searcher_for(&cfg, Algo::Tpe).name(), "batch-tpe");
        cfg.batch_q = QPolicy::Auto;
        assert_eq!(searcher_for(&cfg, Algo::KmeansTpe).name(), "batch-kmeans-tpe");
        assert_eq!(searcher_for(&cfg, Algo::Random).name(), "random");
        assert_eq!(searcher_for(&cfg, Algo::GpBo).name(), "gp-bo");
    }
}
