//! Per-config evaluation-cost model for proactive scheduling.
//!
//! The adaptive-q controller of PR 2 was purely reactive: an EWMA of the
//! previous rounds' wall-clock, blind to WHICH configs were in them. But the
//! cost of a proxy-QAT evaluation is strongly structured — it grows with the
//! total bit budget and the total width multiplier of the candidate (bigger
//! matrices to train, more packing work in the hardware model) — so a tiny
//! linear model over per-config features predicts the cost of a config
//! BEFORE it is evaluated. The scheduler uses that two ways:
//!
//!   (a) *proactive q*: the eval/proposal cost ratio that sizes a batched
//!       round is computed from the model's prediction for the region the
//!       search currently occupies, not from whatever the last round
//!       happened to cost;
//!   (b) *longest-job-first ordering*: a round queue sorted by predicted
//!       cost descending packs well under work stealing — the expensive
//!       evaluations start first and the cheap ones backfill idle workers,
//!       instead of an expensive straggler starting last and stalling the
//!       round tail alone.
//!
//! Features are φ(x) = [1, Σ values of group₀, Σ values of group₁, …, d]:
//! an intercept, the summed *menu values* of each dimension group, and the
//! dimension count d. The coordinator splits dims into a total-bits group
//! and a total-width group via its `DimKind` mapping; callers without a
//! mapping use one group holding every dimension (the total decoded value).
//! Within a single space d is constant and collinear with the intercept —
//! it is carried so a model's weights remain meaningful if checkpoint
//! tooling ever compares fits across (pruned) spaces, and the ridge term
//! keeps the normal equations well-posed despite the collinearity.
//!
//! The fit is online ridge regression on accumulated normal equations
//! (XᵀX + λI)w = Xᵀy: `observe` is O(k²) and re-solves the k×k system
//! (k ≤ 4 here) by Gaussian elimination — microseconds against evaluations
//! that cost milliseconds to minutes.

use super::space::{Config, Space};

/// Ridge strength. Features are O(1)–O(10³) sums and costs are seconds, so
/// an absolute 1e-6 on the Gram diagonal is far below any informative
/// curvature while still bounding the collinear intercept/dim-count pair.
const RIDGE: f64 = 1e-6;

/// Per-observation weight of the feature-mean EWMA: an effective window of
/// ~10 evaluations (2–3 batched rounds), so `predicted_mean` tracks the
/// region the search is narrowing into within a couple of rounds.
const MEAN_ALPHA: f64 = 0.1;

/// Online linear model of per-config evaluation cost (see module docs).
#[derive(Debug, Clone)]
pub struct CostModel {
    space: Space,
    /// Dimension index groups whose summed menu values become one feature
    /// each (e.g. the bits dims and the width dims).
    groups: Vec<Vec<usize>>,
    /// Feature count: 1 (intercept) + groups + 1 (dim count).
    k: usize,
    /// Accumulated Gram matrix XᵀX, row-major k×k.
    xtx: Vec<f64>,
    /// Accumulated Xᵀy.
    xty: Vec<f64>,
    /// RECENCY-WEIGHTED mean of the observed feature vectors (per-obs EWMA,
    /// [`MEAN_ALPHA`]) — the "region the search currently occupies" that
    /// the proactive-q prediction is evaluated at. A cumulative mean would
    /// move by only 1/n per observation and keep quoting the cost of a
    /// region the search left hundreds of evals ago.
    mean_x: Vec<f64>,
    n: usize,
    /// Solved weights, refreshed on every `observe`.
    weights: Option<Vec<f64>>,
}

impl CostModel {
    /// Model over `space` with explicit feature groups. Group indices must
    /// be valid dims of `space`; dims outside every group contribute to no
    /// sum feature (only to the constant dim count).
    pub fn with_groups(space: &Space, groups: Vec<Vec<usize>>) -> CostModel {
        let nd = space.num_dims();
        assert!(
            groups.iter().flatten().all(|&d| d < nd),
            "cost-model feature group references a dim outside the space"
        );
        let k = 2 + groups.len();
        CostModel {
            space: space.clone(),
            groups,
            k,
            xtx: vec![0.0; k * k],
            xty: vec![0.0; k],
            mean_x: vec![0.0; k],
            n: 0,
            weights: None,
        }
    }

    /// Model with a single group holding every dimension — the featureization
    /// available when no bits/width mapping is known (plain `Space`).
    pub fn for_space(space: &Space) -> CostModel {
        let all: Vec<usize> = (0..space.num_dims()).collect();
        CostModel::with_groups(space, vec![all])
    }

    /// φ(config): [1, per-group value sums..., dim count].
    pub fn features(&self, config: &Config) -> Vec<f64> {
        let values = self.space.values(config);
        let mut phi = Vec::with_capacity(self.k);
        phi.push(1.0);
        for group in &self.groups {
            phi.push(group.iter().map(|&d| values[d]).sum());
        }
        phi.push(self.space.num_dims() as f64);
        phi
    }

    /// Fold one observed (config, seconds) pair into the fit. Non-finite or
    /// negative timings (failed evals, clock skew) are ignored — they carry
    /// no cost information and would poison the normal equations.
    pub fn observe(&mut self, config: &Config, secs: f64) {
        if !secs.is_finite() || secs < 0.0 || !self.space.validate(config) {
            return;
        }
        let phi = self.features(config);
        for i in 0..self.k {
            for j in 0..self.k {
                self.xtx[i * self.k + j] += phi[i] * phi[j];
            }
            self.xty[i] += phi[i] * secs;
        }
        self.n += 1;
        // First observation seeds the mean whole; later ones fold in with
        // the recency weight.
        let w = if self.n == 1 { 1.0 } else { MEAN_ALPHA };
        for i in 0..self.k {
            self.mean_x[i] += w * (phi[i] - self.mean_x[i]);
        }
        self.weights = self.solve();
    }

    /// Observations folded in so far.
    pub fn n_obs(&self) -> usize {
        self.n
    }

    /// Whether the fit has seen enough data to schedule by: a couple of
    /// observations per weight. Before this, callers should fall back to
    /// their no-model behavior (saturate q, FIFO queue order).
    pub fn ready(&self) -> bool {
        self.n >= 2 * self.k
    }

    /// Predicted cost of `config`, clamped non-negative (a cost model that
    /// extrapolates below zero must not order queues or size rounds with a
    /// negative duration). `None` until [`ready`](Self::ready).
    pub fn predict(&self, config: &Config) -> Option<f64> {
        if !self.ready() {
            return None;
        }
        let w = self.weights.as_ref()?;
        let phi = self.features(config);
        Some(phi.iter().zip(w).map(|(x, w)| x * w).sum::<f64>().max(0.0))
    }

    /// Prediction at the recency-weighted mean feature vector — the
    /// proactive per-eval cost of "the region the search is currently
    /// proposing in", tracking drift within a couple of rounds as the
    /// search narrows (see [`MEAN_ALPHA`]).
    pub fn predicted_mean(&self) -> Option<f64> {
        if !self.ready() {
            return None;
        }
        let w = self.weights.as_ref()?;
        Some(self.mean_x.iter().zip(w).map(|(x, w)| x * w).sum::<f64>().max(0.0))
    }

    /// Solve (XᵀX + λI)w = Xᵀy by Gaussian elimination with partial
    /// pivoting. k ≤ ~4, so this is a few dozen flops.
    fn solve(&self) -> Option<Vec<f64>> {
        let k = self.k;
        let mut a = self.xtx.clone();
        for i in 0..k {
            a[i * k + i] += RIDGE;
        }
        let mut b = self.xty.clone();
        for col in 0..k {
            let pivot = (col..k)
                .max_by(|&p, &q| {
                    a[p * k + col].abs().total_cmp(&a[q * k + col].abs())
                })
                .expect("non-empty pivot range");
            if a[pivot * k + col].abs() < 1e-300 {
                return None; // numerically singular despite the ridge
            }
            if pivot != col {
                for j in 0..k {
                    a.swap(col * k + j, pivot * k + j);
                }
                b.swap(col, pivot);
            }
            let d = a[col * k + col];
            for row in (col + 1)..k {
                let f = a[row * k + col] / d;
                if f == 0.0 {
                    continue;
                }
                for j in col..k {
                    a[row * k + j] -= f * a[col * k + j];
                }
                b[row] -= f * b[col];
            }
        }
        let mut w = vec![0.0; k];
        for row in (0..k).rev() {
            let mut acc = b[row];
            for j in (row + 1)..k {
                acc -= a[row * k + j] * w[j];
            }
            w[row] = acc / a[row * k + row];
        }
        if w.iter().all(|x| x.is_finite()) {
            Some(w)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::space::Dim;
    use crate::util::rng::Rng;

    fn space(dims: usize) -> Space {
        Space::new(
            (0..dims)
                .map(|d| Dim::new(format!("d{d}"), vec![2.0, 3.0, 4.0, 6.0, 8.0]))
                .collect(),
        )
    }

    #[test]
    fn converges_exactly_on_a_linear_cost() {
        // True cost: 2ms + 0.5ms per unit of total value. The fit must
        // recover it to numerical precision (the data IS linear).
        let s = space(6);
        let mut model = CostModel::for_space(&s);
        let mut rng = Rng::new(3);
        let cost = |c: &Config| 0.002 + 0.0005 * s.values(c).iter().sum::<f64>();
        for _ in 0..40 {
            let c = s.sample(&mut rng);
            model.observe(&c, cost(&c));
        }
        assert!(model.ready());
        for _ in 0..20 {
            let c = s.sample(&mut rng);
            let pred = model.predict(&c).unwrap();
            let truth = cost(&c);
            assert!(
                (pred - truth).abs() < 1e-6 * truth.max(1e-9) + 1e-9,
                "pred {pred} vs truth {truth}"
            );
        }
        // predicted_mean tracks the mean of observed costs.
        let pm = model.predicted_mean().unwrap();
        assert!(pm > 0.002 && pm < 0.002 + 0.0005 * 8.0 * 6.0, "mean pred {pm}");
    }

    #[test]
    fn grouped_features_separate_bits_from_width_costs() {
        // Dims 0..3 are "bits" (cheap), 3..6 are "width" (expensive):
        // cost = 1e-4·Σbits + 1e-2·Σwidth. A grouped model recovers both
        // slopes; predictions order configs by true cost.
        let s = space(6);
        let groups = vec![vec![0, 1, 2], vec![3, 4, 5]];
        let mut model = CostModel::with_groups(&s, groups);
        let mut rng = Rng::new(9);
        let cost = |c: &Config| {
            let v = s.values(c);
            1e-4 * (v[0] + v[1] + v[2]) + 1e-2 * (v[3] + v[4] + v[5])
        };
        for _ in 0..60 {
            let c = s.sample(&mut rng);
            model.observe(&c, cost(&c));
        }
        let cheap: Config = vec![4, 4, 4, 0, 0, 0]; // max bits, min width
        let dear: Config = vec![0, 0, 0, 4, 4, 4]; // min bits, max width
        let (pc, pd) =
            (model.predict(&cheap).unwrap(), model.predict(&dear).unwrap());
        assert!(pd > pc, "grouped model lost the width slope: {pc} vs {pd}");
        assert!((pc - cost(&cheap)).abs() < 1e-6, "cheap pred {pc}");
        assert!((pd - cost(&dear)).abs() < 1e-6, "dear pred {pd}");
    }

    #[test]
    fn not_ready_until_enough_observations_and_ignores_garbage() {
        let s = space(3);
        let mut model = CostModel::for_space(&s);
        assert_eq!(model.predict(&vec![0, 0, 0]), None);
        // Non-finite / negative timings and invalid configs are dropped.
        model.observe(&vec![0, 0, 0], f64::NAN);
        model.observe(&vec![0, 0, 0], -1.0);
        model.observe(&vec![9, 9, 9], 0.5);
        assert_eq!(model.n_obs(), 0);
        let mut rng = Rng::new(1);
        for i in 0..(2 * 3) {
            assert!(!model.ready(), "ready after only {i} observations");
            let c = s.sample(&mut rng);
            model.observe(&c, 0.001);
        }
        assert!(model.ready());
        // Constant cost fits as a pure intercept: every prediction ~0.001.
        let p = model.predict(&vec![2, 2, 2]).unwrap();
        assert!((p - 0.001).abs() < 1e-6, "constant-cost prediction {p}");
    }
}
