//! Synthetic objectives with a controllable evaluation cost.
//!
//! The async worker pool and the adaptive-q controller are exercised against
//! objectives whose *wall-clock* behavior is the variable under test, not
//! their landscape. [`SyntheticObjective`] evaluates the separable
//! `-(sum of choice indices)` landscape (optimum: all dims at choice 0) and
//! optionally sleeps per evaluation, simulating an expensive proxy-QAT run —
//! or a deliberately slow straggler worker. It backs `sammpq worker
//! --synthetic`, the `sammpq pool` demo, the `round-latency` bench, and the
//! pool/adaptive-q tests, so all of them agree on the expected values.

use std::time::Duration;

use super::space::{Config, Dim, Space};
use super::Objective;

/// Separable synthetic objective: value = -(sum of chosen indices), with an
/// optional per-eval sleep to emulate evaluation cost.
pub struct SyntheticObjective {
    space: Space,
    sleep: Duration,
    /// Evaluations served (workers report this at shutdown).
    pub evals: usize,
}

impl SyntheticObjective {
    /// `dims` dimensions with `choices` ordered choices each.
    pub fn new(dims: usize, choices: usize, sleep: Duration) -> SyntheticObjective {
        assert!(dims > 0 && choices > 0, "synthetic space must be non-empty");
        let space = Space::new(
            (0..dims)
                .map(|d| Dim::new(format!("d{d}"), (0..choices).map(|c| c as f64).collect()))
                .collect(),
        );
        SyntheticObjective::with_space(space, sleep)
    }

    /// Serve an arbitrary (e.g. leader-synced) space: the landscape is a
    /// pure function of the choice INDICES, so any categorical space works —
    /// which is what lets a synthetic worker rebuild whatever pruned space a
    /// leader hands it in the session handshake.
    pub fn with_space(space: Space, sleep: Duration) -> SyntheticObjective {
        assert!(space.num_dims() > 0, "synthetic space must be non-empty");
        SyntheticObjective { space, sleep, evals: 0 }
    }

    /// The value `eval` returns for `config` — lets tests and remote
    /// leaders check results without an objective instance of their own.
    pub fn expected_value(config: &Config) -> f64 {
        -(config.iter().sum::<usize>() as f64)
    }
}

impl Objective for SyntheticObjective {
    fn space(&self) -> &Space {
        &self.space
    }

    fn eval(&mut self, config: &Config) -> f64 {
        if !self.sleep.is_zero() {
            std::thread::sleep(self.sleep);
        }
        self.evals += 1;
        Self::expected_value(config)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values_match_expected_and_optimum_is_zero() {
        let mut obj = SyntheticObjective::new(3, 4, Duration::ZERO);
        assert_eq!(obj.eval(&vec![0, 0, 0]), 0.0);
        assert_eq!(obj.eval(&vec![3, 2, 1]), -6.0);
        assert_eq!(obj.evals, 2);
        assert_eq!(SyntheticObjective::expected_value(&vec![1, 1, 1]), -3.0);
        assert!(obj.space().validate(&vec![3, 3, 3]));
        assert!(!obj.space().validate(&vec![4, 0, 0]));
    }
}
