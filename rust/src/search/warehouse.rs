//! Cross-session transfer store: an on-disk [`EvalRecord`] warehouse that
//! warm-starts searches from the fleet's history (`--warehouse <dir>`).
//!
//! At production scale most searches are near-duplicates of searches some
//! leader has already paid for, yet every session starts its surrogates
//! cold and re-evaluates configs whose metrics sit in a checkpoint nobody
//! reads. The warehouse closes that loop:
//!
//! * every completed search APPENDS its fresh records under a key derived
//!   from the space it searched ([`Space::fingerprint`]) plus a digest of
//!   the objective + hardware config (same space, different J-weights or
//!   target device must never cross-pollinate);
//! * on session start the leader LOOKS UP the warehouse — an
//!   exact-fingerprint hit seeds the surrogates resume-style AND
//!   pre-populates the config-keyed eval cache, so already-paid configs
//!   are served from disk instead of the farm; a near miss (overlapping
//!   dim names / choice values) is remapped through
//!   [`SpaceProjection`] with the [`ProjectionReport`] logged, seeding
//!   surrogates only (projected configs are approximate evidence, never
//!   cache-served as exact).
//!
//! On-disk layout, under the warehouse root:
//!
//! ```text
//! manifest.json                      advisory index (atomic tmp+rename;
//!                                    readers always fall back to a scan)
//! <fingerprint>-<digest>/            one directory per key
//!   space.json                       the space the records index into
//!   seg-<session>.jsonl              one append-only segment PER SESSION
//! ```
//!
//! Multi-leader safety comes from segment ownership: a session only ever
//! rewrites its OWN segment (read-modify-write, atomic tmp+rename), so
//! concurrent leaders on a shared warehouse never clobber each other.
//! Readers merge all segments, tolerate a torn trailing line exactly like
//! `CheckpointStore` tolerates a torn checkpoint, and deduplicate on
//! (config, value bit-pattern). `sammpq warehouse ls|gc` gives operators
//! inspection and size-capped retention (oldest segments evicted first).

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::evaluator::EvalRecord;
use crate::util::hash::Fnv1a;
use crate::util::json::{obj, Json};

use super::project::{ProjectPolicy, ProjectionReport, SpaceProjection};
use super::space::{Config, Space};

/// File name of the warehouse's advisory index.
pub const WAREHOUSE_MANIFEST: &str = "manifest.json";

/// Digest a set of config strings (objective knobs, hardware model) into
/// the 16-hex suffix of a warehouse key. Order-sensitive and
/// length-prefix-framed, so `["ab", "c"]` and `["a", "bc"]` differ.
pub fn cfg_digest(parts: &[&str]) -> String {
    let mut h = Fnv1a::new();
    for p in parts {
        h.write_u64(p.len() as u64);
        h.write(p.as_bytes());
    }
    h.hex()
}

/// The warehouse key a (space, objective/hw digest) pair files under.
pub fn warehouse_key(space: &Space, digest: &str) -> String {
    format!("{}-{digest}", space.fingerprint())
}

/// Split a key back into (space fingerprint, cfg digest). Returns `None`
/// for directory names that are not warehouse keys.
fn split_key(key: &str) -> Option<(&str, &str)> {
    let (fp, digest) = key.split_at(key.find('-')?);
    let digest = &digest[1..];
    let hex16 = |s: &str| s.len() == 16 && s.bytes().all(|b| b.is_ascii_hexdigit());
    (hex16(fp) && hex16(digest)).then_some((fp, digest))
}

/// Everything stored under one key: the space the configs index into and
/// the merged, deduplicated record set across all segments.
#[derive(Debug, Clone)]
pub struct StoredHistory {
    pub space: Space,
    pub records: Vec<EvalRecord>,
}

/// One key's `warehouse ls` row.
#[derive(Debug, Clone)]
pub struct KeySummary {
    pub key: String,
    pub dims: usize,
    /// Deduplicated record count across segments.
    pub records: usize,
    pub segments: usize,
    /// Total segment bytes (the quantity `gc` caps).
    pub bytes: u64,
}

/// What a `warehouse gc` pass did.
#[derive(Debug, Clone, Copy, Default)]
pub struct GcOutcome {
    pub deleted_segments: usize,
    /// Keys whose last segment was evicted (their directory is removed).
    pub deleted_keys: usize,
    pub freed_bytes: u64,
    pub kept_bytes: u64,
}

/// A warm-start hit, ready to feed `BatchSearcher::start_warm`.
#[derive(Debug, Clone)]
pub enum WarmStart {
    /// Exact fingerprint + digest match: records replay verbatim — seed
    /// the surrogates AND the config-keyed eval cache.
    Exact { key: String, records: Vec<EvalRecord> },
    /// Overlapping space under the same digest, remapped through
    /// [`SpaceProjection`]: seed the surrogates ONLY (projected configs
    /// are approximate evidence). `configs` is empty when the candidate
    /// shared zero real dims — the report is still returned so the
    /// degenerate case is visible, but nothing is seeded.
    Projected {
        key: String,
        configs: Vec<Config>,
        values: Vec<f64>,
        report: ProjectionReport,
    },
}

impl WarmStart {
    /// Trials this hit actually seeds into the surrogates.
    pub fn seeded(&self) -> usize {
        match self {
            WarmStart::Exact { records, .. } => records.len(),
            WarmStart::Projected { configs, .. } => configs.len(),
        }
    }
}

/// Handle on a warehouse directory. Cheap to open; every operation goes
/// back to disk, so concurrent leaders coordinate through the filesystem
/// alone (rename atomicity), never through shared in-process state.
pub struct Warehouse {
    dir: PathBuf,
    /// THIS session's segment file name — the only file it rewrites.
    segment: String,
}

impl Warehouse {
    /// Open (creating if needed) with a caller-chosen session tag. Tags
    /// are sanitized to `[A-Za-z0-9._-]`, and two sessions with the same
    /// tag share a segment — fine for a deliberate re-run (dedup absorbs
    /// replays), wrong for concurrent leaders, so production callers use
    /// [`open`](Self::open).
    pub fn open_tagged(dir: &Path, tag: &str) -> Result<Warehouse> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create warehouse {}", dir.display()))?;
        let tag: String = tag
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || "._-".contains(c) { c } else { '_' })
            .collect();
        anyhow::ensure!(!tag.is_empty(), "empty warehouse session tag");
        Ok(Warehouse { dir: dir.to_path_buf(), segment: format!("seg-{tag}.jsonl") })
    }

    /// Open with a process-unique session tag (pid + wall-clock nanos):
    /// concurrent leaders on one warehouse land in distinct segments.
    pub fn open(dir: &Path) -> Result<Warehouse> {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        Warehouse::open_tagged(dir, &format!("{}-{nanos:x}", std::process::id()))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn key_dir(&self, key: &str) -> PathBuf {
        self.dir.join(key)
    }

    /// Keys present on disk (directory scan, sorted — the manifest is
    /// advisory and never trusted for reads).
    pub fn keys(&self) -> Result<Vec<String>> {
        let mut keys = Vec::new();
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("list warehouse {}", self.dir.display()))?
        {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if entry.path().is_dir() && split_key(&name).is_some() {
                keys.push(name);
            }
        }
        keys.sort();
        Ok(keys)
    }

    /// Append `records` under `key`, writing `space.json` on first touch.
    /// Only finite-valued records are stored (failure sentinels are cheap
    /// to rediscover and must never be served as paid evidence), and
    /// records already present in THIS session's segment are skipped —
    /// (config, value-bits) dedup makes round-by-round appends idempotent.
    /// Returns how many records were actually added.
    pub fn append(&self, key: &str, space: &Space, records: &[EvalRecord]) -> Result<usize> {
        anyhow::ensure!(
            key.starts_with(&space.fingerprint()),
            "warehouse key '{key}' does not match the space fingerprint {}",
            space.fingerprint()
        );
        let kd = self.key_dir(key);
        std::fs::create_dir_all(&kd)?;
        let space_path = kd.join("space.json");
        if !space_path.exists() {
            let tmp = kd.join("space.tmp");
            std::fs::write(&tmp, space.to_json().to_string_pretty() + "\n")?;
            std::fs::rename(&tmp, &space_path)
                .with_context(|| format!("commit {}", space_path.display()))?;
        }
        let seg = kd.join(&self.segment);
        let mut kept = read_segment(&seg);
        let mut seen: HashSet<(Config, u64)> =
            kept.iter().map(|r| (r.config.clone(), r.value.to_bits())).collect();
        let before = kept.len();
        for r in records {
            if !r.value.is_finite() || !space.validate(&r.config) {
                continue;
            }
            if seen.insert((r.config.clone(), r.value.to_bits())) {
                kept.push(r.clone());
            }
        }
        let added = kept.len() - before;
        if added == 0 {
            return Ok(0);
        }
        let mut text = String::new();
        for r in &kept {
            text.push_str(&r.to_json().to_string_compact());
            text.push('\n');
        }
        let tmp = seg.with_extension("tmp");
        std::fs::write(&tmp, text)?;
        std::fs::rename(&tmp, &seg).with_context(|| format!("commit {}", seg.display()))?;
        self.write_manifest()?;
        Ok(added)
    }

    /// Merge every segment under `key`: records in segment-name order,
    /// deduplicated on (config, value-bits), torn tails tolerated. `None`
    /// when the key (or its `space.json`) does not exist.
    pub fn load(&self, key: &str) -> Result<Option<StoredHistory>> {
        let kd = self.key_dir(key);
        let space_path = kd.join("space.json");
        if !space_path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&space_path)
            .with_context(|| format!("read {}", space_path.display()))?;
        let j = Json::parse(text.trim())
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", space_path.display()))?;
        let space = Space::from_json(&j)?;
        let mut records = Vec::new();
        let mut seen: HashSet<(Config, u64)> = HashSet::new();
        for seg in segments_of(&kd)? {
            for r in read_segment(&kd.join(&seg)) {
                if seen.insert((r.config.clone(), r.value.to_bits())) {
                    records.push(r);
                }
            }
        }
        Ok(Some(StoredHistory { space, records }))
    }

    /// Per-key `ls` rows, sorted by key.
    pub fn summaries(&self) -> Result<Vec<KeySummary>> {
        let mut out = Vec::new();
        for key in self.keys()? {
            let kd = self.key_dir(&key);
            let segs = segments_of(&kd)?;
            let bytes = segs
                .iter()
                .filter_map(|s| std::fs::metadata(kd.join(s)).ok())
                .map(|m| m.len())
                .sum();
            let (dims, records) = match self.load(&key)? {
                Some(st) => (st.space.num_dims(), st.records.len()),
                None => (0, 0),
            };
            out.push(KeySummary { key, dims, records, segments: segs.len(), bytes });
        }
        Ok(out)
    }

    /// Whole-store totals — (keys, deduplicated records, segment bytes) —
    /// from a fresh scan. What the serve daemon's `GET /metrics` reports
    /// as the shared warehouse's size.
    pub fn stats(&self) -> Result<(usize, usize, u64)> {
        let mut records = 0usize;
        let mut bytes = 0u64;
        let summaries = self.summaries()?;
        for s in &summaries {
            records += s.records;
            bytes += s.bytes;
        }
        Ok((summaries.len(), records, bytes))
    }

    /// Size-capped retention: evict whole segments, oldest mtime first
    /// (ties break by key then segment name, so a replay is
    /// deterministic), until total segment bytes fit `max_bytes`. A key
    /// whose last segment goes loses its directory too.
    pub fn gc(&self, max_bytes: u64) -> Result<GcOutcome> {
        let mut segs: Vec<(std::time::SystemTime, String, String, u64)> = Vec::new();
        for key in self.keys()? {
            let kd = self.key_dir(&key);
            for name in segments_of(&kd)? {
                let meta = std::fs::metadata(kd.join(&name))?;
                let mtime = meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
                segs.push((mtime, key.clone(), name, meta.len()));
            }
        }
        segs.sort_by(|a, b| (a.0, &a.1, &a.2).cmp(&(b.0, &b.1, &b.2)));
        let mut total: u64 = segs.iter().map(|s| s.3).sum();
        let mut out = GcOutcome::default();
        let mut emptied: HashSet<String> = HashSet::new();
        for (_, key, name, bytes) in &segs {
            if total <= max_bytes {
                break;
            }
            std::fs::remove_file(self.key_dir(key).join(name))?;
            total -= bytes;
            out.deleted_segments += 1;
            out.freed_bytes += bytes;
            emptied.insert(key.clone());
        }
        for key in emptied {
            let kd = self.key_dir(&key);
            if segments_of(&kd)?.is_empty() {
                let _ = std::fs::remove_file(kd.join("space.json"));
                if std::fs::remove_dir(&kd).is_ok() {
                    out.deleted_keys += 1;
                }
            }
        }
        out.kept_bytes = total;
        self.write_manifest()?;
        Ok(out)
    }

    /// Find the best warm-start for `space` under `digest`:
    ///
    /// 1. the exact key `fingerprint-digest`, replayed verbatim;
    /// 2. else, among same-digest keys, the stored space sharing the MOST
    ///    dim names with `space` (ties: more records, then lower key) is
    ///    projected through [`SpaceProjection::between`] +
    ///    `project_trials` under `policy`;
    /// 3. zero-overlap candidates seed NOTHING — the projection would be
    ///    pure prior fill, i.e. noise dressed as evidence — but the
    ///    report still comes back so the degenerate case is logged.
    ///
    /// `Ok(None)` when the warehouse holds nothing usable for this digest.
    pub fn lookup(
        &self,
        space: &Space,
        digest: &str,
        policy: ProjectPolicy,
    ) -> Result<Option<WarmStart>> {
        let exact_key = warehouse_key(space, digest);
        if let Some(st) = self.load(&exact_key)? {
            let fp = space.fingerprint();
            anyhow::ensure!(
                st.space.fingerprint() == fp,
                "warehouse key {exact_key} stores fingerprint {} (corrupt space.json?)",
                st.space.fingerprint()
            );
            let records: Vec<EvalRecord> = st
                .records
                .into_iter()
                .filter(|r| r.value.is_finite() && space.validate(&r.config))
                .collect();
            if !records.is_empty() {
                return Ok(Some(WarmStart::Exact { key: exact_key, records }));
            }
        }
        // Near miss: best same-digest candidate by real dim overlap.
        let mut best: Option<(usize, usize, String, StoredHistory)> = None;
        for key in self.keys()? {
            let Some((fp, d)) = split_key(&key) else { continue };
            if d != digest || fp == space.fingerprint() {
                continue;
            }
            let Some(st) = self.load(&key)? else { continue };
            if st.records.is_empty() {
                continue;
            }
            let matched = SpaceProjection::between(&st.space, space).matched_dims();
            let better = match &best {
                None => true,
                Some((bm, bn, bk, _)) => {
                    (matched, st.records.len(), std::cmp::Reverse(&key))
                        > (*bm, *bn, std::cmp::Reverse(bk))
                }
            };
            if better {
                best = Some((matched, st.records.len(), key, st));
            }
        }
        let Some((matched, _, key, st)) = best else {
            return Ok(None);
        };
        let proj = SpaceProjection::between(&st.space, space);
        let stored: Vec<Config> = st.records.iter().map(|r| r.config.clone()).collect();
        let (map, report) = proj.project_trials(&stored, space, policy);
        let mut configs = Vec::new();
        let mut values = Vec::new();
        if matched > 0 {
            for (m, r) in map.iter().zip(&st.records) {
                if let Some(c) = m {
                    if r.value.is_finite() && space.validate(c) {
                        configs.push(c.clone());
                        values.push(r.value);
                    }
                }
            }
        }
        Ok(Some(WarmStart::Projected { key, configs, values, report }))
    }

    /// Rewrite the advisory manifest from a full scan (atomic tmp+rename).
    fn write_manifest(&self) -> Result<()> {
        let mut keys = Vec::new();
        for s in self.summaries()? {
            keys.push((
                s.key.clone(),
                obj(vec![
                    ("dims", Json::Num(s.dims as f64)),
                    ("records", Json::Num(s.records as f64)),
                    ("segments", Json::Num(s.segments as f64)),
                    ("bytes", Json::Num(s.bytes as f64)),
                ]),
            ));
        }
        let manifest = obj(vec![
            ("version", Json::Num(1.0)),
            (
                "keys",
                Json::Obj(keys.into_iter().collect()),
            ),
        ]);
        let tmp = self.dir.join("manifest.tmp");
        std::fs::write(&tmp, manifest.to_string_pretty() + "\n")?;
        std::fs::rename(&tmp, self.dir.join(WAREHOUSE_MANIFEST))
            .with_context(|| format!("commit manifest in {}", self.dir.display()))?;
        Ok(())
    }
}

/// Segment file names under a key directory, sorted (deterministic merge
/// order).
fn segments_of(kd: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    for entry in
        std::fs::read_dir(kd).with_context(|| format!("list {}", kd.display()))?
    {
        let name = entry?.file_name().to_string_lossy().into_owned();
        if name.starts_with("seg-") && name.ends_with(".jsonl") {
            names.push(name);
        }
    }
    names.sort();
    Ok(names)
}

/// Parse a segment, tolerating a torn tail: a trailing line that fails to
/// parse is the crash-mid-append case and is skipped silently; garbage
/// EARLIER in the file is unexpected and warned about, but never fatal —
/// a damaged warehouse degrades to fewer warm-start seeds, not a dead
/// leader. A missing file is an empty segment.
fn read_segment(path: &Path) -> Vec<EvalRecord> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Vec::new();
    };
    let lines: Vec<&str> = text.lines().collect();
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let rec = Json::parse(line)
            .ok()
            .and_then(|j| EvalRecord::from_json(&j).ok());
        match rec {
            Some(r) => out.push(r),
            None if i + 1 == lines.len() => {} // torn tail
            None => eprintln!(
                "[warehouse] {}: skipping unparseable line {}",
                path.display(),
                i + 1
            ),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::space::Dim;

    fn temp_warehouse(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("sammpq_wh_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn space_ab() -> Space {
        Space::new(vec![
            Dim::new("bits:a", vec![8.0, 6.0, 4.0]),
            Dim::new("bits:b", vec![6.0, 4.0]),
        ])
    }

    fn rec(config: Config, value: f64) -> EvalRecord {
        EvalRecord::value_only(config, value)
    }

    #[test]
    fn append_load_roundtrip_dedup_and_manifest() {
        let dir = temp_warehouse("rt");
        let wh = Warehouse::open_tagged(&dir, "s1").unwrap();
        let space = space_ab();
        let key = warehouse_key(&space, &cfg_digest(&["obj", "hw"]));
        let records = vec![
            rec(vec![0, 0], 0.5),
            rec(vec![1, 1], 0.7),
            rec(vec![0, 0], 0.5),              // duplicate (config, value)
            rec(vec![0, 0], 0.6),              // same config, NEW value: kept
            rec(vec![2, 1], f64::NEG_INFINITY), // failure sentinel: skipped
            rec(vec![9, 9], 0.9),              // invalid for the space: skipped
        ];
        assert_eq!(wh.append(&key, &space, &records).unwrap(), 3);
        // Idempotent: a replayed round adds nothing.
        assert_eq!(wh.append(&key, &space, &records).unwrap(), 0);
        let st = wh.load(&key).unwrap().unwrap();
        assert_eq!(st.space.fingerprint(), space.fingerprint());
        assert_eq!(st.records.len(), 3);
        assert_eq!(st.records[0], records[0]);
        // Manifest exists and names the key; readers never require it.
        let manifest = Json::parse(
            std::fs::read_to_string(dir.join(WAREHOUSE_MANIFEST)).unwrap().trim(),
        )
        .unwrap();
        assert!(manifest.get("keys").and_then(|k| k.get(&key)).is_some());
        assert_eq!(wh.summaries().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn segments_merge_across_sessions_and_tolerate_torn_tails() {
        let dir = temp_warehouse("seg");
        let space = space_ab();
        let key = warehouse_key(&space, &cfg_digest(&["o"]));
        let a = Warehouse::open_tagged(&dir, "a").unwrap();
        let b = Warehouse::open_tagged(&dir, "b").unwrap();
        a.append(&key, &space, &[rec(vec![0, 0], 0.5), rec(vec![1, 0], 0.4)]).unwrap();
        // Session b re-pays one of a's trials: the merged view dedups it.
        b.append(&key, &space, &[rec(vec![0, 0], 0.5), rec(vec![2, 1], 0.8)]).unwrap();
        let st = a.load(&key).unwrap().unwrap();
        assert_eq!(st.records.len(), 3);
        // Torn tail: a crash mid-append leaves a half-written last line.
        let seg = dir.join(&key).join("seg-b.jsonl");
        let mut text = std::fs::read_to_string(&seg).unwrap();
        text.push_str("{\"config\": [1, 1], \"val");
        std::fs::write(&seg, text).unwrap();
        let st = a.load(&key).unwrap().unwrap();
        assert_eq!(st.records.len(), 3, "torn tail must not poison the segment");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_evicts_oldest_segments_until_under_cap() {
        let dir = temp_warehouse("gc");
        let space = space_ab();
        let key = warehouse_key(&space, &cfg_digest(&["o"]));
        let a = Warehouse::open_tagged(&dir, "a").unwrap();
        let b = Warehouse::open_tagged(&dir, "b").unwrap();
        a.append(&key, &space, &[rec(vec![0, 0], 0.5)]).unwrap();
        b.append(&key, &space, &[rec(vec![1, 1], 0.6), rec(vec![2, 0], 0.7)]).unwrap();
        // Make segment a unambiguously older than b's.
        let old = std::time::SystemTime::now() - std::time::Duration::from_secs(3600);
        let _ = filetime_set(&dir.join(&key).join("seg-a.jsonl"), old);
        let b_bytes = std::fs::metadata(dir.join(&key).join("seg-b.jsonl")).unwrap().len();
        let out = a.gc(b_bytes).unwrap();
        assert_eq!(out.deleted_segments, 1);
        assert!(out.kept_bytes <= b_bytes);
        assert!(!dir.join(&key).join("seg-a.jsonl").exists());
        assert_eq!(a.load(&key).unwrap().unwrap().records.len(), 2);
        // Cap 0 evicts everything, including the emptied key directory.
        let out = a.gc(0).unwrap();
        assert_eq!(out.deleted_segments, 1);
        assert_eq!(out.deleted_keys, 1);
        assert!(!dir.join(&key).exists());
        assert!(a.keys().unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Best-effort mtime rewind so the gc test's age ordering is explicit
    /// rather than racing sub-second timestamps.
    fn filetime_set(path: &Path, to: std::time::SystemTime) -> std::io::Result<()> {
        let f = std::fs::File::options().append(true).open(path)?;
        f.set_modified(to)
    }

    #[test]
    fn lookup_prefers_exact_hit_and_isolates_digests() {
        let dir = temp_warehouse("exact");
        let wh = Warehouse::open_tagged(&dir, "s").unwrap();
        let space = space_ab();
        let d1 = cfg_digest(&["obj-v1"]);
        let d2 = cfg_digest(&["obj-v2"]);
        wh.append(&warehouse_key(&space, &d1), &space, &[rec(vec![0, 0], 0.5)]).unwrap();
        match wh.lookup(&space, &d1, ProjectPolicy::Nearest).unwrap() {
            Some(WarmStart::Exact { records, .. }) => {
                assert_eq!(records, vec![rec(vec![0, 0], 0.5)]);
            }
            other => panic!("expected exact hit, got {other:?}"),
        }
        // Same space, different objective digest: no hit at all.
        assert!(wh.lookup(&space, &d2, ProjectPolicy::Nearest).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lookup_projects_near_miss_and_gates_zero_overlap() {
        let dir = temp_warehouse("near");
        let wh = Warehouse::open_tagged(&dir, "s").unwrap();
        let digest = cfg_digest(&["obj"]);
        let stored = space_ab();
        wh.append(
            &warehouse_key(&stored, &digest),
            &stored,
            &[rec(vec![0, 0], 0.5), rec(vec![2, 1], 0.9)],
        )
        .unwrap();
        // Near miss: bits:a pruned to its top half, bits:b unchanged.
        let near = Space::new(vec![
            Dim::new("bits:a", vec![8.0, 6.0]),
            Dim::new("bits:b", vec![6.0, 4.0]),
        ]);
        match wh.lookup(&near, &digest, ProjectPolicy::Nearest).unwrap() {
            Some(WarmStart::Projected { configs, values, report, .. }) => {
                assert_eq!(report.total(), 2);
                assert_eq!(report.kept + report.snapped, 2);
                assert_eq!(configs.len(), 2);
                assert_eq!(values, vec![0.5, 0.9]);
                for c in &configs {
                    assert!(near.validate(c));
                }
            }
            other => panic!("expected projected hit, got {other:?}"),
        }
        // Zero shared dims: the report comes back clean (everything is
        // prior-fill, nothing kept) but NOTHING is seeded.
        let alien = Space::new(vec![Dim::new("bits:z", vec![8.0, 4.0])]);
        match wh.lookup(&alien, &digest, ProjectPolicy::Nearest).unwrap() {
            Some(WarmStart::Projected { configs, values, report, .. }) => {
                assert_eq!(report.kept, 0);
                assert_eq!(report.total(), 2);
                assert!(configs.is_empty(), "zero-overlap must seed nothing");
                assert!(values.is_empty());
            }
            other => panic!("expected gated projected hit, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lookup_picks_the_candidate_with_most_shared_dims() {
        let dir = temp_warehouse("rank");
        let wh = Warehouse::open_tagged(&dir, "s").unwrap();
        let digest = cfg_digest(&["obj"]);
        let one_dim = Space::new(vec![Dim::new("bits:a", vec![8.0, 6.0, 4.0])]);
        wh.append(&warehouse_key(&one_dim, &digest), &one_dim, &[rec(vec![0], 0.1)])
            .unwrap();
        let two_dim = space_ab();
        wh.append(
            &warehouse_key(&two_dim, &digest),
            &two_dim,
            &[rec(vec![1, 1], 0.8)],
        )
        .unwrap();
        let target = Space::new(vec![
            Dim::new("bits:a", vec![8.0, 6.0]),
            Dim::new("bits:b", vec![6.0, 4.0]),
            Dim::new("bits:c", vec![4.0, 2.0]),
        ]);
        match wh.lookup(&target, &digest, ProjectPolicy::Nearest).unwrap() {
            Some(WarmStart::Projected { key, values, .. }) => {
                assert_eq!(key, warehouse_key(&two_dim, &digest));
                assert_eq!(values, vec![0.8]);
            }
            other => panic!("expected projected hit, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_and_digest_are_stable_and_parseable() {
        let space = space_ab();
        let d = cfg_digest(&["a", "bc"]);
        assert_ne!(d, cfg_digest(&["ab", "c"]), "framing must be length-prefixed");
        assert_eq!(d, cfg_digest(&["a", "bc"]));
        let key = warehouse_key(&space, &d);
        let (fp, back) = split_key(&key).unwrap();
        assert_eq!(fp, space.fingerprint());
        assert_eq!(back, d);
        assert!(split_key("not-a-key").is_none());
        assert!(split_key("manifest.json").is_none());
    }
}
