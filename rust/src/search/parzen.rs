//! Parzen surrogate over a categorical space (Bergstra-style smoothed
//! categorical densities, factorized over dimensions).
//!
//! For dimension d with K_d choices and member counts n_(d,c):
//!     p_d(c) = (n_(d,c) + w0) / (N + K_d * w0)
//! where w0 is the prior pseudo-count. `l(x)` and `g(x)` are two instances
//! fit on the desirable / undesirable populations; the TPE acquisition
//! maximizes `log l(x) - log g(x)`.
//!
//! The surrogate is maintained INCREMENTALLY: it stores a flat
//! struct-of-arrays pseudo-count table (prior included) plus per-dim totals,
//! so adding or removing one config costs O(dims) instead of a refit over
//! the whole population. Counts move by exactly 1.0, which f64 represents
//! exactly below 2^52, so an incrementally maintained instance matches a
//! from-scratch [`Parzen::fit`] bit-for-bit (covered by tests).
//!
//! # Hot-path layout
//!
//! The proposal loop is the searcher's per-iteration cost, so the surrogate
//! keeps lazily rebuilt per-dim lookup tables next to the counts:
//!
//! * `log_prob[off_d + c] = ln(counts[off_d + c] / totals[d])` — scoring a
//!   candidate ([`log_ratio`], [`log_pdf`]) becomes a flat gather-and-sum
//!   over contiguous arrays (no division, no `ln` per candidate), which the
//!   compiler can autovectorize. Each table entry is computed by exactly the
//!   division + `ln` the scalar path used, so scores are bit-identical.
//! * `thresh[off_d + c]` — per-choice sampling thresholds: the largest
//!   `u >= 0` for which `Rng::weighted`'s sequential subtraction scan over
//!   this dim's counts would return a choice `<= c`. The scan is monotone
//!   non-decreasing in `u` (f64 subtraction is monotone), so these
//!   thresholds exist and are found by a ~64-step binary search over the
//!   non-negative f64 bit patterns. Sampling then draws the same
//!   `u = f64() * total` (against `total_seq`, the cached SEQUENTIAL sum the
//!   scan uses — the incrementally maintained `totals` can differ in the
//!   last bit) and binary-searches the thresholds — one RNG draw per dim,
//!   bit-identical choices, O(log K) instead of O(K) per dim.
//!
//! Tables are invalidated per-dim by [`add`](Parzen::add) /
//! [`remove`](Parzen::remove) and rebuilt lazily on first use (a `RefCell`
//! keeps the read paths `&self`), so a retarget storm between proposals
//! costs O(changed * dims) count updates plus ONE table rebuild of the
//! touched dims — not a rebuild per update.

use super::space::{Config, Space};
use crate::util::rng::Rng;
use std::cell::{Ref, RefCell};

/// Lazily rebuilt per-dim lookup tables (see module docs). Lives behind a
/// `RefCell` so `sample`/`log_ratio`-style read paths stay `&self`.
#[derive(Debug, Clone)]
struct Tables {
    /// Flat `ln(count/total)` per (dim, choice) — the scoring gather table.
    log_prob: Vec<f64>,
    /// Flat per-(dim, choice) sampling thresholds (see module docs); the
    /// last choice of every dim holds `+inf`.
    thresh: Vec<f64>,
    /// Per-dim SEQUENTIAL count sum — bit-exact what `Rng::weighted`
    /// computes internally, which may differ in the last bit from the
    /// incrementally maintained `totals`.
    total_seq: Vec<f64>,
    /// Per-dim staleness flags, set by `add`/`remove`.
    dirty: Vec<bool>,
    /// Fast path: false once every dim is clean.
    any_dirty: bool,
}

#[derive(Debug, Clone)]
pub struct Parzen {
    /// Flat per-dim, per-choice pseudo-counts (the prior weight is baked
    /// in); dim `d` occupies `offsets[d]..offsets[d + 1]`.
    counts: Vec<f64>,
    /// Dim -> start index into the flat arrays (`dims + 1` entries).
    offsets: Vec<usize>,
    /// Per-dim count totals (sum over choices), maintained alongside.
    totals: Vec<f64>,
    /// The prior pseudo-count every choice starts from — kept on the struct
    /// so `remove` can assert a decremented count never falls below it
    /// (which would mean removing a config that was never added).
    prior_weight: f64,
    tables: RefCell<Tables>,
}

impl Parzen {
    /// An empty-population surrogate: every count is the prior pseudo-count,
    /// i.e. the uniform prior over each dimension.
    pub fn new_prior(space: &Space, prior_weight: f64) -> Parzen {
        assert!(
            prior_weight > 0.0 && prior_weight.is_finite(),
            "prior_weight must be positive and finite, got {prior_weight}"
        );
        let mut offsets = Vec::with_capacity(space.dims.len() + 1);
        offsets.push(0usize);
        for dim in &space.dims {
            offsets.push(offsets.last().unwrap() + dim.k());
        }
        let flat = *offsets.last().unwrap();
        let counts = vec![prior_weight; flat];
        let totals: Vec<f64> =
            space.dims.iter().map(|dim| prior_weight * dim.k() as f64).collect();
        let dims = space.dims.len();
        Parzen {
            counts,
            offsets,
            totals,
            prior_weight,
            tables: RefCell::new(Tables {
                log_prob: vec![0.0; flat],
                thresh: vec![0.0; flat],
                total_seq: vec![0.0; dims],
                dirty: vec![true; dims],
                any_dirty: true,
            }),
        }
    }

    /// Fit from a population of configs. `prior_weight` > 0 keeps every
    /// choice reachable even with tiny populations.
    pub fn fit(space: &Space, population: &[&Config], prior_weight: f64) -> Parzen {
        let mut p = Parzen::new_prior(space, prior_weight);
        for cfg in population {
            p.add(cfg);
        }
        p
    }

    fn num_dims(&self) -> usize {
        self.totals.len()
    }

    /// Add one config to the population: O(dims).
    pub fn add(&mut self, config: &Config) {
        let t = self.tables.get_mut();
        for (d, &c) in config.iter().enumerate() {
            self.counts[self.offsets[d] + c] += 1.0;
            self.totals[d] += 1.0;
            t.dirty[d] = true;
        }
        t.any_dirty = true;
    }

    /// Remove one previously added config: O(dims). Exact inverse of [`add`].
    pub fn remove(&mut self, config: &Config) {
        let t = self.tables.get_mut();
        for (d, &c) in config.iter().enumerate() {
            self.counts[self.offsets[d] + c] -= 1.0;
            self.totals[d] -= 1.0;
            t.dirty[d] = true;
            // Every legitimately removable count is prior + (n >= 1), so the
            // decrement can never land BELOW the bare prior. Checking `> 0`
            // here used to let a never-added removal slip through whenever
            // prior_weight > 1.0 (prior - 1.0 still positive) — the
            // surrogate would silently carry a corrupted population.
            debug_assert!(
                self.counts[self.offsets[d] + c] >= self.prior_weight,
                "Parzen::remove of a config that was never added (dim {d})"
            );
        }
        t.any_dirty = true;
    }

    /// Rebuild the lookup tables of every dirty dim, then hand out a shared
    /// borrow. Cheap when clean: one flag check.
    fn tables(&self) -> Ref<'_, Tables> {
        if self.tables.borrow().any_dirty {
            let mut t = self.tables.borrow_mut();
            for d in 0..self.num_dims() {
                if !t.dirty[d] {
                    continue;
                }
                let off = self.offsets[d];
                let k = self.offsets[d + 1] - off;
                let counts = &self.counts[off..off + k];
                for c in 0..k {
                    t.log_prob[off + c] = (counts[c] / self.totals[d]).ln();
                }
                // The SEQUENTIAL sum `Rng::weighted` computes — NOT the
                // incrementally maintained total, which can differ in the
                // last bit (e.g. prior 0.7 summed 3x vs 0.7 * 3).
                t.total_seq[d] = counts.iter().sum();
                // `Rng::weighted`'s subtraction scan as a pure function of u.
                let scan = |u0: f64| -> usize {
                    let mut u = u0;
                    for (i, w) in counts.iter().enumerate() {
                        u -= w;
                        if u <= 0.0 {
                            return i;
                        }
                    }
                    k - 1
                };
                for i in 0..k {
                    // Largest u with scan(u) <= i; the scan is monotone
                    // non-decreasing in u, and non-negative f64 bit patterns
                    // order like the values, so a bitwise binary search
                    // finds the EXACT boundary. scan(+inf) == k - 1 (the
                    // fallback), so the last threshold is always +inf.
                    t.thresh[off + i] = if scan(f64::INFINITY) <= i {
                        f64::INFINITY
                    } else {
                        let mut lo = 0u64; // scan(0) == 0 <= i always
                        let mut hi = f64::INFINITY.to_bits();
                        while hi - lo > 1 {
                            let mid = lo + (hi - lo) / 2;
                            if scan(f64::from_bits(mid)) <= i {
                                lo = mid;
                            } else {
                                hi = mid;
                            }
                        }
                        f64::from_bits(lo)
                    };
                }
                t.dirty[d] = false;
            }
            t.any_dirty = false;
        }
        self.tables.borrow()
    }

    pub fn log_pdf(&self, config: &Config) -> f64 {
        let t = self.tables();
        config.iter().enumerate().map(|(d, &c)| t.log_prob[self.offsets[d] + c]).sum()
    }

    /// Draw one choice for dim `d` — the threshold tables replay
    /// `Rng::weighted` exactly: same single `f64()` draw scaled by the same
    /// sequential total, resolved by binary search instead of a linear scan.
    #[inline]
    fn draw(&self, t: &Tables, d: usize, rng: &mut Rng) -> usize {
        let off = self.offsets[d];
        let u = rng.f64() * t.total_seq[d];
        // First index whose threshold is >= u == what the scan returns; the
        // last threshold is +inf, so the result is always in range.
        t.thresh[off..self.offsets[d + 1]].partition_point(|&x| x < u)
    }

    pub fn sample(&self, rng: &mut Rng) -> Config {
        let t = self.tables();
        (0..self.num_dims()).map(|d| self.draw(&t, d, rng)).collect()
    }

    /// Sample into an existing buffer — the proposal hot path draws tens of
    /// candidates per call and reuses one scratch `Config` across them
    /// instead of allocating a fresh `Vec` per draw. Draws the same RNG
    /// sequence as [`sample`](Self::sample).
    pub fn sample_into(&self, out: &mut Config, rng: &mut Rng) {
        let t = self.tables();
        out.clear();
        out.extend((0..self.num_dims()).map(|d| self.draw(&t, d, rng)));
    }

    pub fn prob(&self, dim: usize, choice: usize) -> f64 {
        self.counts[self.offsets[dim] + choice] / self.totals[dim]
    }

    /// Raw pseudo-count (prior included) — used by the exactness tests.
    pub fn count(&self, dim: usize, choice: usize) -> f64 {
        self.counts[self.offsets[dim] + choice]
    }

    /// Exact structural equality of counts (and therefore of all densities).
    pub fn same_counts(&self, other: &Parzen) -> bool {
        self.offsets == other.offsets
            && self.counts == other.counts
            && self.totals == other.totals
    }
}

/// A diff-maintained l(x)/g(x) pair. Searchers re-point the desirable and
/// undesirable populations every iteration (cluster membership and quantile
/// membership both drift as history grows); `retarget` applies only the
/// membership CHANGES to the two Parzens, so the per-iteration surrogate
/// cost is O(changed · dims) instead of a full O(n · dims) refit — while
/// staying exactly equal to a from-scratch fit of the same member sets.
#[derive(Debug, Clone)]
pub struct SurrogatePair {
    pub l: Parzen,
    pub g: Parzen,
    in_l: Vec<bool>,
    in_g: Vec<bool>,
}

impl SurrogatePair {
    pub fn new(space: &Space, prior_weight: f64) -> SurrogatePair {
        SurrogatePair {
            l: Parzen::new_prior(space, prior_weight),
            g: Parzen::new_prior(space, prior_weight),
            in_l: Vec::new(),
            in_g: Vec::new(),
        }
    }

    /// Re-point the populations: `new_l[i]` / `new_g[i]` say whether trial
    /// `i` (with config `configs[i]`) belongs to the desirable / undesirable
    /// population. Only flips are applied to the Parzens.
    pub fn retarget(&mut self, configs: &[Config], new_l: &[bool], new_g: &[bool]) {
        debug_assert_eq!(configs.len(), new_l.len());
        debug_assert_eq!(configs.len(), new_g.len());
        self.in_l.resize(configs.len(), false);
        self.in_g.resize(configs.len(), false);
        for i in 0..configs.len() {
            if new_l[i] != self.in_l[i] {
                if new_l[i] {
                    self.l.add(&configs[i]);
                } else {
                    self.l.remove(&configs[i]);
                }
                self.in_l[i] = new_l[i];
            }
            if new_g[i] != self.in_g[i] {
                if new_g[i] {
                    self.g.add(&configs[i]);
                } else {
                    self.g.remove(&configs[i]);
                }
                self.in_g[i] = new_g[i];
            }
        }
    }
}

/// The acquisition score log l(x) − log g(x): a flat gather-and-sum over the
/// two precomputed log-prob tables (no division or `ln` per call — each
/// table entry was computed by exactly the scalar arithmetic this replaced,
/// so the sum is bit-identical).
pub fn log_ratio(l: &Parzen, g: &Parzen, config: &Config) -> f64 {
    let lt = l.tables();
    let gt = g.tables();
    config
        .iter()
        .enumerate()
        .map(|(d, &c)| {
            let i = l.offsets[d] + c;
            lt.log_prob[i] - gt.log_prob[i]
        })
        .sum()
}

/// Acquisition: draw `n_candidates` from `l`, return the one maximizing
/// log l - log g (the l/g ratio of §III-B). `n_candidates == 0` degrades to
/// a single draw from `l` instead of panicking (see KmeansTpeParams
/// validation for the strict guard).
///
/// Called tens of times per proposal round. All candidates are drawn first
/// into one flat buffer (same RNG stream as drawing-then-scoring one at a
/// time — scoring consumes no randomness), scored by gathering from the
/// precomputed log-prob tables, and the winner is lifted out with a single
/// `select_nth_unstable_by` partial sort. Pseudo-counts are
/// >= prior_weight > 0 with finite totals, so every score is finite and the
/// (score desc, index asc) comparator is a total order whose minimum is
/// exactly the FIRST maximum — the same candidate the old compare-as-you-go
/// loop kept.
pub fn propose(
    l: &Parzen,
    g: &Parzen,
    rng: &mut Rng,
    n_candidates: usize,
) -> Config {
    let n = n_candidates.max(1);
    let dims = l.num_dims();
    let lt = l.tables();
    let gt = g.tables();
    // Candidate-major flat buffer; drawing all before scoring keeps the RNG
    // stream identical to the draw-score-draw-score loop this replaced.
    let mut flat: Vec<usize> = Vec::with_capacity(n * dims);
    for _ in 0..n {
        for d in 0..dims {
            flat.push(l.draw(&lt, d, rng));
        }
    }
    let scores: Vec<f64> = (0..n)
        .map(|j| {
            flat[j * dims..(j + 1) * dims]
                .iter()
                .enumerate()
                .map(|(d, &c)| {
                    let i = l.offsets[d] + c;
                    lt.log_prob[i] - gt.log_prob[i]
                })
                .sum()
        })
        .collect();
    let mut order: Vec<usize> = (0..n).collect();
    order.select_nth_unstable_by(0, |&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let best = order[0];
    flat[best * dims..(best + 1) * dims].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::space::Dim;

    fn space() -> Space {
        Space::new(vec![
            Dim::new("a", vec![0.0, 1.0, 2.0]),
            Dim::new("b", vec![0.0, 1.0]),
        ])
    }

    #[test]
    fn fit_reflects_counts() {
        let s = space();
        let pop_owned: Vec<Config> = vec![vec![0, 0], vec![0, 1], vec![0, 0]];
        let pop: Vec<&Config> = pop_owned.iter().collect();
        let p = Parzen::fit(&s, &pop, 0.5);
        assert!(p.prob(0, 0) > p.prob(0, 1));
        assert!(p.prob(0, 1) > 0.0); // prior keeps it reachable
        let total: f64 = (0..3).map(|c| p.prob(0, c)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_population_is_uniform() {
        let s = space();
        let p = Parzen::fit(&s, &[], 1.0);
        for c in 0..3 {
            assert!((p.prob(0, c) - 1.0 / 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn log_pdf_factorizes() {
        let s = space();
        let pop_owned: Vec<Config> = vec![vec![1, 1]];
        let pop: Vec<&Config> = pop_owned.iter().collect();
        let p = Parzen::fit(&s, &pop, 1.0);
        let lp = p.log_pdf(&vec![1, 1]);
        assert!((lp - (p.prob(0, 1).ln() + p.prob(1, 1).ln())).abs() < 1e-12);
    }

    #[test]
    fn propose_prefers_l_region() {
        let s = space();
        let l_pop_owned: Vec<Config> = vec![vec![2, 1]; 10];
        let g_pop_owned: Vec<Config> = vec![vec![0, 0]; 10];
        let l = Parzen::fit(&s, &l_pop_owned.iter().collect::<Vec<_>>(), 0.1);
        let g = Parzen::fit(&s, &g_pop_owned.iter().collect::<Vec<_>>(), 0.1);
        let mut rng = Rng::new(0);
        let mut hits = 0;
        for _ in 0..50 {
            if propose(&l, &g, &mut rng, 16) == vec![2, 1] {
                hits += 1;
            }
        }
        assert!(hits > 40, "hits={hits}");
    }

    #[test]
    fn sample_distribution_matches_probs() {
        let s = space();
        let pop_owned: Vec<Config> = vec![vec![2, 0]; 20];
        let p = Parzen::fit(&s, &pop_owned.iter().collect::<Vec<_>>(), 0.5);
        let mut rng = Rng::new(1);
        let mut count2 = 0;
        let n = 2_000;
        for _ in 0..n {
            if p.sample(&mut rng)[0] == 2 {
                count2 += 1;
            }
        }
        let freq = count2 as f64 / n as f64;
        assert!((freq - p.prob(0, 2)).abs() < 0.05, "freq={freq}");
    }

    #[test]
    fn zero_candidates_does_not_panic() {
        let s = space();
        let l = Parzen::fit(&s, &[], 1.0);
        let g = Parzen::fit(&s, &[], 1.0);
        let mut rng = Rng::new(2);
        let c = propose(&l, &g, &mut rng, 0);
        assert!(s.validate(&c));
    }

    #[test]
    fn sample_into_matches_sample_and_log_ratio_matches_pdfs() {
        let s = space();
        let pop_owned: Vec<Config> = vec![vec![2, 1], vec![0, 0], vec![2, 0]];
        let l = Parzen::fit(&s, &pop_owned.iter().collect::<Vec<_>>(), 0.5);
        let g = Parzen::fit(&s, &pop_owned[..1].iter().collect::<Vec<_>>(), 0.5);

        // Same seed => sample and sample_into draw identical sequences.
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let mut buf = Config::new();
        for _ in 0..20 {
            let a = l.sample(&mut r1);
            l.sample_into(&mut buf, &mut r2);
            assert_eq!(a, buf);
            let lr = log_ratio(&l, &g, &a);
            assert!((lr - (l.log_pdf(&a) - g.log_pdf(&a))).abs() < 1e-12);
        }
    }

    #[test]
    fn incremental_add_remove_matches_fit_exactly() {
        let s = space();
        let mut rng = Rng::new(3);
        let pop: Vec<Config> = (0..40).map(|_| s.sample(&mut rng)).collect();

        // Add all, remove a scattered subset; compare with a fresh fit of
        // the surviving population. Counts must match EXACTLY (no epsilon).
        let mut inc = Parzen::new_prior(&s, 0.7);
        for c in &pop {
            inc.add(c);
        }
        let survivors: Vec<&Config> =
            pop.iter().enumerate().filter(|(i, _)| i % 3 != 0).map(|(_, c)| c).collect();
        for (i, c) in pop.iter().enumerate() {
            if i % 3 == 0 {
                inc.remove(c);
            }
        }
        let fresh = Parzen::fit(&s, &survivors, 0.7);
        assert!(inc.same_counts(&fresh));
    }

    #[test]
    fn surrogate_pair_retarget_matches_fit() {
        let s = space();
        let mut rng = Rng::new(4);
        let configs: Vec<Config> = (0..30).map(|_| s.sample(&mut rng)).collect();
        let mut pair = SurrogatePair::new(&s, 1.0);

        // Three successive re-targetings with overlapping member sets.
        for round in 0..3 {
            let in_l: Vec<bool> = (0..configs.len()).map(|i| (i + round) % 4 == 0).collect();
            let in_g: Vec<bool> = (0..configs.len()).map(|i| (i + round) % 5 == 0).collect();
            pair.retarget(&configs, &in_l, &in_g);

            let l_pop: Vec<&Config> =
                configs.iter().enumerate().filter(|(i, _)| in_l[*i]).map(|(_, c)| c).collect();
            let g_pop: Vec<&Config> =
                configs.iter().enumerate().filter(|(i, _)| in_g[*i]).map(|(_, c)| c).collect();
            assert!(pair.l.same_counts(&Parzen::fit(&s, &l_pop, 1.0)), "round {round} l");
            assert!(pair.g.same_counts(&Parzen::fit(&s, &g_pop, 1.0)), "round {round} g");
        }
    }

    /// The bug the stored prior fixes: with prior_weight > 1.0 the old
    /// `> 0.0` assert stayed silent on a never-added removal (prior - 1.0 is
    /// still positive) — the count must never fall below the bare prior.
    /// debug_assert-only, so the guard is checked where it exists.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "never added")]
    fn remove_of_never_added_config_panics() {
        let s = space();
        let mut p = Parzen::new_prior(&s, 2.0);
        p.add(&vec![0, 0]);
        p.remove(&vec![1, 1]); // never added: counts fall to prior - 1.0
    }

    /// The threshold tables must replay `Rng::weighted`'s subtraction scan
    /// EXACTLY: same seed => identical choice sequences over a lumpy count
    /// table, including after incremental updates dirty the tables.
    #[test]
    fn threshold_sampling_matches_weighted_reference() {
        let s = Space::new(vec![
            Dim::new("a", (0..7).map(|c| c as f64).collect::<Vec<_>>()),
            Dim::new("b", vec![0.0, 1.0]),
            Dim::new("c", (0..5).map(|c| c as f64).collect::<Vec<_>>()),
        ]);
        let mut rng = Rng::new(11);
        let pop: Vec<Config> = (0..60).map(|_| s.sample(&mut rng)).collect();
        let mut p = Parzen::fit(&s, &pop.iter().collect::<Vec<_>>(), 0.3);
        for round in 0..3 {
            let mut r_fast = Rng::new(100 + round);
            let mut r_ref = Rng::new(100 + round);
            for _ in 0..500 {
                let fast = p.sample(&mut r_fast);
                // Reference: the pre-table scan over the same raw counts.
                let reference: Config = (0..s.dims.len())
                    .map(|d| {
                        let w: Vec<f64> =
                            (0..s.dims[d].k()).map(|c| p.count(d, c)).collect();
                        r_ref.weighted(&w)
                    })
                    .collect();
                assert_eq!(fast, reference);
            }
            // Dirty the tables and check again on the updated counts.
            p.add(&pop[round as usize]);
            p.remove(&pop[round as usize + 10]);
            p.add(&pop[round as usize + 10]); // net: one extra member
        }
    }

    /// Gathered table scores must equal the scalar recompute BIT-FOR-BIT
    /// (each table entry is produced by the same division + ln).
    #[test]
    fn table_log_ratio_is_bit_identical_to_recompute() {
        let s = space();
        let mut rng = Rng::new(12);
        let pop: Vec<Config> = (0..25).map(|_| s.sample(&mut rng)).collect();
        let l = Parzen::fit(&s, &pop.iter().collect::<Vec<_>>(), 0.7);
        let g = Parzen::fit(&s, &pop[..8].iter().collect::<Vec<_>>(), 0.7);
        for cfg in &pop {
            let scalar: f64 = cfg
                .iter()
                .enumerate()
                .map(|(d, &c)| l.prob(d, c).ln() - g.prob(d, c).ln())
                .sum();
            assert_eq!(log_ratio(&l, &g, cfg).to_bits(), scalar.to_bits());
        }
    }

    /// With l == g every candidate scores exactly 0.0; the partial sort must
    /// keep the FIRST candidate drawn — the old compare-as-you-go loop's
    /// tie-break.
    #[test]
    fn propose_keeps_first_candidate_on_ties() {
        let s = space();
        let l = Parzen::fit(&s, &[], 1.0);
        let g = Parzen::fit(&s, &[], 1.0);
        for seed in 0..20 {
            let mut r_prop = Rng::new(seed);
            let mut r_first = Rng::new(seed);
            let picked = propose(&l, &g, &mut r_prop, 8);
            let first = l.sample(&mut r_first);
            assert_eq!(picked, first, "seed {seed}");
        }
    }
}
