//! Parzen surrogate over a categorical space (Bergstra-style smoothed
//! categorical densities, factorized over dimensions).
//!
//! For dimension d with K_d choices and member counts n_(d,c):
//!     p_d(c) = (n_(d,c) + w0) / (N + K_d * w0)
//! where w0 is the prior pseudo-count. `l(x)` and `g(x)` are two instances
//! fit on the desirable / undesirable populations; the TPE acquisition
//! maximizes `log l(x) - log g(x)`.

use super::space::{Config, Space};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Parzen {
    /// Per-dim, per-choice probabilities (already normalized).
    probs: Vec<Vec<f64>>,
}

impl Parzen {
    /// Fit from a population of configs. `prior_weight` > 0 keeps every
    /// choice reachable even with tiny populations.
    pub fn fit(space: &Space, population: &[&Config], prior_weight: f64) -> Parzen {
        assert!(prior_weight > 0.0);
        let probs = space
            .dims
            .iter()
            .enumerate()
            .map(|(d, dim)| {
                let k = dim.k();
                let mut counts = vec![prior_weight; k];
                for cfg in population {
                    counts[cfg[d]] += 1.0;
                }
                let total: f64 = counts.iter().sum();
                counts.iter().map(|c| c / total).collect()
            })
            .collect();
        Parzen { probs }
    }

    pub fn log_pdf(&self, config: &Config) -> f64 {
        config
            .iter()
            .enumerate()
            .map(|(d, &c)| self.probs[d][c].ln())
            .sum()
    }

    pub fn sample(&self, rng: &mut Rng) -> Config {
        self.probs.iter().map(|p| rng.weighted(p)).collect()
    }

    pub fn prob(&self, dim: usize, choice: usize) -> f64 {
        self.probs[dim][choice]
    }
}

/// Acquisition: draw `n_candidates` from `l`, return the one maximizing
/// log l - log g (the l/g ratio of §III-B).
pub fn propose(
    l: &Parzen,
    g: &Parzen,
    rng: &mut Rng,
    n_candidates: usize,
) -> Config {
    let mut best: Option<(f64, Config)> = None;
    for _ in 0..n_candidates {
        let cand = l.sample(rng);
        let score = l.log_pdf(&cand) - g.log_pdf(&cand);
        if best.as_ref().map_or(true, |(s, _)| score > *s) {
            best = Some((score, cand));
        }
    }
    best.unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::space::Dim;

    fn space() -> Space {
        Space::new(vec![
            Dim::new("a", vec![0.0, 1.0, 2.0]),
            Dim::new("b", vec![0.0, 1.0]),
        ])
    }

    #[test]
    fn fit_reflects_counts() {
        let s = space();
        let pop_owned: Vec<Config> = vec![vec![0, 0], vec![0, 1], vec![0, 0]];
        let pop: Vec<&Config> = pop_owned.iter().collect();
        let p = Parzen::fit(&s, &pop, 0.5);
        assert!(p.prob(0, 0) > p.prob(0, 1));
        assert!(p.prob(0, 1) > 0.0); // prior keeps it reachable
        let total: f64 = (0..3).map(|c| p.prob(0, c)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_population_is_uniform() {
        let s = space();
        let p = Parzen::fit(&s, &[], 1.0);
        for c in 0..3 {
            assert!((p.prob(0, c) - 1.0 / 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn log_pdf_factorizes() {
        let s = space();
        let pop_owned: Vec<Config> = vec![vec![1, 1]];
        let pop: Vec<&Config> = pop_owned.iter().collect();
        let p = Parzen::fit(&s, &pop, 1.0);
        let lp = p.log_pdf(&vec![1, 1]);
        assert!((lp - (p.prob(0, 1).ln() + p.prob(1, 1).ln())).abs() < 1e-12);
    }

    #[test]
    fn propose_prefers_l_region() {
        let s = space();
        let l_pop_owned: Vec<Config> = vec![vec![2, 1]; 10];
        let g_pop_owned: Vec<Config> = vec![vec![0, 0]; 10];
        let l = Parzen::fit(&s, &l_pop_owned.iter().collect::<Vec<_>>(), 0.1);
        let g = Parzen::fit(&s, &g_pop_owned.iter().collect::<Vec<_>>(), 0.1);
        let mut rng = Rng::new(0);
        let mut hits = 0;
        for _ in 0..50 {
            if propose(&l, &g, &mut rng, 16) == vec![2, 1] {
                hits += 1;
            }
        }
        assert!(hits > 40, "hits={hits}");
    }

    #[test]
    fn sample_distribution_matches_probs() {
        let s = space();
        let pop_owned: Vec<Config> = vec![vec![2, 0]; 20];
        let p = Parzen::fit(&s, &pop_owned.iter().collect::<Vec<_>>(), 0.5);
        let mut rng = Rng::new(1);
        let mut count2 = 0;
        let n = 2_000;
        for _ in 0..n {
            if p.sample(&mut rng)[0] == 2 {
                count2 += 1;
            }
        }
        let freq = count2 as f64 / n as f64;
        assert!((freq - p.prob(0, 2)).abs() < 0.05, "freq={freq}");
    }
}
