//! Parzen surrogate over a categorical space (Bergstra-style smoothed
//! categorical densities, factorized over dimensions).
//!
//! For dimension d with K_d choices and member counts n_(d,c):
//!     p_d(c) = (n_(d,c) + w0) / (N + K_d * w0)
//! where w0 is the prior pseudo-count. `l(x)` and `g(x)` are two instances
//! fit on the desirable / undesirable populations; the TPE acquisition
//! maximizes `log l(x) - log g(x)`.
//!
//! The surrogate is maintained INCREMENTALLY: it stores per-dim pseudo-count
//! vectors (prior included) plus per-dim totals, so adding or removing one
//! config costs O(dims) instead of a refit over the whole population. Counts
//! move by exactly 1.0, which f64 represents exactly below 2^52, so an
//! incrementally maintained instance matches a from-scratch [`Parzen::fit`]
//! bit-for-bit (covered by tests).

use super::space::{Config, Space};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Parzen {
    /// Per-dim, per-choice pseudo-counts (the prior weight is baked in).
    counts: Vec<Vec<f64>>,
    /// Per-dim count totals (sum over choices), maintained alongside.
    totals: Vec<f64>,
}

impl Parzen {
    /// An empty-population surrogate: every count is the prior pseudo-count,
    /// i.e. the uniform prior over each dimension.
    pub fn new_prior(space: &Space, prior_weight: f64) -> Parzen {
        assert!(
            prior_weight > 0.0 && prior_weight.is_finite(),
            "prior_weight must be positive and finite, got {prior_weight}"
        );
        let counts: Vec<Vec<f64>> =
            space.dims.iter().map(|dim| vec![prior_weight; dim.k()]).collect();
        let totals = counts.iter().map(|c| prior_weight * c.len() as f64).collect();
        Parzen { counts, totals }
    }

    /// Fit from a population of configs. `prior_weight` > 0 keeps every
    /// choice reachable even with tiny populations.
    pub fn fit(space: &Space, population: &[&Config], prior_weight: f64) -> Parzen {
        let mut p = Parzen::new_prior(space, prior_weight);
        for cfg in population {
            p.add(cfg);
        }
        p
    }

    /// Add one config to the population: O(dims).
    pub fn add(&mut self, config: &Config) {
        for (d, &c) in config.iter().enumerate() {
            self.counts[d][c] += 1.0;
            self.totals[d] += 1.0;
        }
    }

    /// Remove one previously added config: O(dims). Exact inverse of [`add`].
    pub fn remove(&mut self, config: &Config) {
        for (d, &c) in config.iter().enumerate() {
            self.counts[d][c] -= 1.0;
            self.totals[d] -= 1.0;
            debug_assert!(
                self.counts[d][c] > 0.0,
                "Parzen::remove of a config that was never added (dim {d})"
            );
        }
    }

    pub fn log_pdf(&self, config: &Config) -> f64 {
        config
            .iter()
            .enumerate()
            .map(|(d, &c)| (self.counts[d][c] / self.totals[d]).ln())
            .sum()
    }

    pub fn sample(&self, rng: &mut Rng) -> Config {
        // `Rng::weighted` accepts unnormalized non-negative weights, so the
        // raw pseudo-counts sample the same distribution as the probs.
        self.counts.iter().map(|c| rng.weighted(c)).collect()
    }

    /// Sample into an existing buffer — the proposal hot path draws tens of
    /// candidates per call and reuses one scratch `Config` across them
    /// instead of allocating a fresh `Vec` per draw. Draws the same RNG
    /// sequence as [`sample`](Self::sample).
    pub fn sample_into(&self, out: &mut Config, rng: &mut Rng) {
        out.clear();
        out.extend(self.counts.iter().map(|c| rng.weighted(c)));
    }

    pub fn prob(&self, dim: usize, choice: usize) -> f64 {
        self.counts[dim][choice] / self.totals[dim]
    }

    /// Raw pseudo-count (prior included) — used by the exactness tests.
    pub fn count(&self, dim: usize, choice: usize) -> f64 {
        self.counts[dim][choice]
    }

    /// Exact structural equality of counts (and therefore of all densities).
    pub fn same_counts(&self, other: &Parzen) -> bool {
        self.counts == other.counts && self.totals == other.totals
    }
}

/// A diff-maintained l(x)/g(x) pair. Searchers re-point the desirable and
/// undesirable populations every iteration (cluster membership and quantile
/// membership both drift as history grows); `retarget` applies only the
/// membership CHANGES to the two Parzens, so the per-iteration surrogate
/// cost is O(changed · dims) instead of a full O(n · dims) refit — while
/// staying exactly equal to a from-scratch fit of the same member sets.
#[derive(Debug, Clone)]
pub struct SurrogatePair {
    pub l: Parzen,
    pub g: Parzen,
    in_l: Vec<bool>,
    in_g: Vec<bool>,
}

impl SurrogatePair {
    pub fn new(space: &Space, prior_weight: f64) -> SurrogatePair {
        SurrogatePair {
            l: Parzen::new_prior(space, prior_weight),
            g: Parzen::new_prior(space, prior_weight),
            in_l: Vec::new(),
            in_g: Vec::new(),
        }
    }

    /// Re-point the populations: `new_l[i]` / `new_g[i]` say whether trial
    /// `i` (with config `configs[i]`) belongs to the desirable / undesirable
    /// population. Only flips are applied to the Parzens.
    pub fn retarget(&mut self, configs: &[Config], new_l: &[bool], new_g: &[bool]) {
        debug_assert_eq!(configs.len(), new_l.len());
        debug_assert_eq!(configs.len(), new_g.len());
        self.in_l.resize(configs.len(), false);
        self.in_g.resize(configs.len(), false);
        for i in 0..configs.len() {
            if new_l[i] != self.in_l[i] {
                if new_l[i] {
                    self.l.add(&configs[i]);
                } else {
                    self.l.remove(&configs[i]);
                }
                self.in_l[i] = new_l[i];
            }
            if new_g[i] != self.in_g[i] {
                if new_g[i] {
                    self.g.add(&configs[i]);
                } else {
                    self.g.remove(&configs[i]);
                }
                self.in_g[i] = new_g[i];
            }
        }
    }
}

/// The acquisition score log l(x) − log g(x), computed in a single pass
/// over the dimensions (one division + one `ln` per surrogate per dim,
/// instead of two separate `log_pdf` traversals).
pub fn log_ratio(l: &Parzen, g: &Parzen, config: &Config) -> f64 {
    config
        .iter()
        .enumerate()
        .map(|(d, &c)| {
            (l.counts[d][c] / l.totals[d]).ln() - (g.counts[d][c] / g.totals[d]).ln()
        })
        .sum()
}

/// Acquisition: draw `n_candidates` from `l`, return the one maximizing
/// log l - log g (the l/g ratio of §III-B). `n_candidates == 0` degrades to
/// a single draw from `l` instead of panicking (see KmeansTpeParams
/// validation for the strict guard).
///
/// Called tens of times per proposal round, so candidates are drawn into a
/// reused scratch buffer ([`Parzen::sample_into`]) and scored in one pass
/// ([`log_ratio`]) — the only per-call allocations are the scratch and the
/// returned winner. The RNG stream and the kept candidate (first maximum
/// wins ties) are identical to the allocating version this replaced.
pub fn propose(
    l: &Parzen,
    g: &Parzen,
    rng: &mut Rng,
    n_candidates: usize,
) -> Config {
    let mut scratch = Config::new();
    let mut best = Config::new();
    let mut best_score = f64::NEG_INFINITY;
    for _ in 0..n_candidates.max(1) {
        l.sample_into(&mut scratch, rng);
        let score = log_ratio(l, g, &scratch);
        // Pseudo-counts are >= prior_weight > 0 with finite totals, so the
        // score is always finite and the first candidate always replaces the
        // empty initial `best`.
        if score > best_score {
            best_score = score;
            std::mem::swap(&mut best, &mut scratch);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::space::Dim;

    fn space() -> Space {
        Space::new(vec![
            Dim::new("a", vec![0.0, 1.0, 2.0]),
            Dim::new("b", vec![0.0, 1.0]),
        ])
    }

    #[test]
    fn fit_reflects_counts() {
        let s = space();
        let pop_owned: Vec<Config> = vec![vec![0, 0], vec![0, 1], vec![0, 0]];
        let pop: Vec<&Config> = pop_owned.iter().collect();
        let p = Parzen::fit(&s, &pop, 0.5);
        assert!(p.prob(0, 0) > p.prob(0, 1));
        assert!(p.prob(0, 1) > 0.0); // prior keeps it reachable
        let total: f64 = (0..3).map(|c| p.prob(0, c)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_population_is_uniform() {
        let s = space();
        let p = Parzen::fit(&s, &[], 1.0);
        for c in 0..3 {
            assert!((p.prob(0, c) - 1.0 / 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn log_pdf_factorizes() {
        let s = space();
        let pop_owned: Vec<Config> = vec![vec![1, 1]];
        let pop: Vec<&Config> = pop_owned.iter().collect();
        let p = Parzen::fit(&s, &pop, 1.0);
        let lp = p.log_pdf(&vec![1, 1]);
        assert!((lp - (p.prob(0, 1).ln() + p.prob(1, 1).ln())).abs() < 1e-12);
    }

    #[test]
    fn propose_prefers_l_region() {
        let s = space();
        let l_pop_owned: Vec<Config> = vec![vec![2, 1]; 10];
        let g_pop_owned: Vec<Config> = vec![vec![0, 0]; 10];
        let l = Parzen::fit(&s, &l_pop_owned.iter().collect::<Vec<_>>(), 0.1);
        let g = Parzen::fit(&s, &g_pop_owned.iter().collect::<Vec<_>>(), 0.1);
        let mut rng = Rng::new(0);
        let mut hits = 0;
        for _ in 0..50 {
            if propose(&l, &g, &mut rng, 16) == vec![2, 1] {
                hits += 1;
            }
        }
        assert!(hits > 40, "hits={hits}");
    }

    #[test]
    fn sample_distribution_matches_probs() {
        let s = space();
        let pop_owned: Vec<Config> = vec![vec![2, 0]; 20];
        let p = Parzen::fit(&s, &pop_owned.iter().collect::<Vec<_>>(), 0.5);
        let mut rng = Rng::new(1);
        let mut count2 = 0;
        let n = 2_000;
        for _ in 0..n {
            if p.sample(&mut rng)[0] == 2 {
                count2 += 1;
            }
        }
        let freq = count2 as f64 / n as f64;
        assert!((freq - p.prob(0, 2)).abs() < 0.05, "freq={freq}");
    }

    #[test]
    fn zero_candidates_does_not_panic() {
        let s = space();
        let l = Parzen::fit(&s, &[], 1.0);
        let g = Parzen::fit(&s, &[], 1.0);
        let mut rng = Rng::new(2);
        let c = propose(&l, &g, &mut rng, 0);
        assert!(s.validate(&c));
    }

    #[test]
    fn sample_into_matches_sample_and_log_ratio_matches_pdfs() {
        let s = space();
        let pop_owned: Vec<Config> = vec![vec![2, 1], vec![0, 0], vec![2, 0]];
        let l = Parzen::fit(&s, &pop_owned.iter().collect::<Vec<_>>(), 0.5);
        let g = Parzen::fit(&s, &pop_owned[..1].iter().collect::<Vec<_>>(), 0.5);

        // Same seed => sample and sample_into draw identical sequences.
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let mut buf = Config::new();
        for _ in 0..20 {
            let a = l.sample(&mut r1);
            l.sample_into(&mut buf, &mut r2);
            assert_eq!(a, buf);
            let lr = log_ratio(&l, &g, &a);
            assert!((lr - (l.log_pdf(&a) - g.log_pdf(&a))).abs() < 1e-12);
        }
    }

    #[test]
    fn incremental_add_remove_matches_fit_exactly() {
        let s = space();
        let mut rng = Rng::new(3);
        let pop: Vec<Config> = (0..40).map(|_| s.sample(&mut rng)).collect();

        // Add all, remove a scattered subset; compare with a fresh fit of
        // the surviving population. Counts must match EXACTLY (no epsilon).
        let mut inc = Parzen::new_prior(&s, 0.7);
        for c in &pop {
            inc.add(c);
        }
        let survivors: Vec<&Config> =
            pop.iter().enumerate().filter(|(i, _)| i % 3 != 0).map(|(_, c)| c).collect();
        for (i, c) in pop.iter().enumerate() {
            if i % 3 == 0 {
                inc.remove(c);
            }
        }
        let fresh = Parzen::fit(&s, &survivors, 0.7);
        assert!(inc.same_counts(&fresh));
    }

    #[test]
    fn surrogate_pair_retarget_matches_fit() {
        let s = space();
        let mut rng = Rng::new(4);
        let configs: Vec<Config> = (0..30).map(|_| s.sample(&mut rng)).collect();
        let mut pair = SurrogatePair::new(&s, 1.0);

        // Three successive re-targetings with overlapping member sets.
        for round in 0..3 {
            let in_l: Vec<bool> = (0..configs.len()).map(|i| (i + round) % 4 == 0).collect();
            let in_g: Vec<bool> = (0..configs.len()).map(|i| (i + round) % 5 == 0).collect();
            pair.retarget(&configs, &in_l, &in_g);

            let l_pop: Vec<&Config> =
                configs.iter().enumerate().filter(|(i, _)| in_l[*i]).map(|(_, c)| c).collect();
            let g_pop: Vec<&Config> =
                configs.iter().enumerate().filter(|(i, _)| in_g[*i]).map(|(_, c)| c).collect();
            assert!(pair.l.same_counts(&Parzen::fit(&s, &l_pop, 1.0)), "round {round} l");
            assert!(pair.g.same_counts(&Parzen::fit(&s, &g_pop, 1.0)), "round {round} g");
        }
    }
}
