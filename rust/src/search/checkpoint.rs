//! Resumable search state — the searcher half of session checkpointing.
//!
//! A [`SearchCheckpoint`] captures everything a batched TPE-family run needs
//! to continue as if it had never stopped: the trial history (configs,
//! values, timings), the proposer's annealing round counter and warm-start
//! centroids, and the RNG cursor. Restoring is EXACT for fixed-q policies:
//! the surrogate Parzens are pure functions of (history, clustering), the
//! clustering warm-starts from the checkpointed centroids, and the restored
//! RNG draws the identical stream — so a resumed run's remaining trials are
//! bit-for-bit the trials the interrupted run would have produced (tested in
//! `search::batch`). Adaptive q (`QPolicy::Auto`) re-tunes from scratch
//! after a resume; its decisions are wall-clock-driven and were never
//! reproducible across runs in the first place.
//!
//! The coordinator wraps this in its own session checkpoint (which adds the
//! full `EvalRecord` log and leader metadata) — see `coordinator::leader`.

use anyhow::Context;

use super::history::{History, Trial};
use super::space::{config_from_json, config_to_json, Config, Space};
use crate::util::json::{dec_f64, dec_f64_arr, enc_f64, enc_f64_arr, obj, Json};
use crate::util::rng::Rng;

/// Serializable RNG cursor (xoshiro256** words + the pending Box-Muller
/// spare). The 64-bit words are hex strings: JSON numbers are f64 and would
/// corrupt anything above 2^53.
#[derive(Debug, Clone, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub gauss_spare: Option<f64>,
}

impl RngState {
    pub fn of(rng: &Rng) -> RngState {
        let (s, gauss_spare) = rng.state();
        RngState { s, gauss_spare }
    }

    pub fn to_rng(&self) -> Rng {
        Rng::from_state(self.s, self.gauss_spare)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "s",
                Json::Arr(self.s.iter().map(|w| Json::Str(format!("{w:016x}"))).collect()),
            ),
            (
                "gauss_spare",
                match self.gauss_spare {
                    Some(g) => enc_f64(g),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<RngState> {
        let words = j.req("s")?.as_arr().context("rng words")?;
        anyhow::ensure!(words.len() == 4, "rng state needs 4 words, got {}", words.len());
        let mut s = [0u64; 4];
        for (i, w) in words.iter().enumerate() {
            let hex = w.as_str().context("rng word must be a hex string")?;
            s[i] = u64::from_str_radix(hex, 16)
                .with_context(|| format!("bad rng word '{hex}'"))?;
        }
        let gauss_spare = match j.req("gauss_spare")? {
            Json::Null => None,
            g => Some(dec_f64(g).context("gauss_spare")?),
        };
        Ok(RngState { s, gauss_spare })
    }
}

/// One batched search run, frozen at a round boundary.
#[derive(Debug, Clone)]
pub struct SearchCheckpoint {
    /// Searcher name ("batch-kmeans-tpe" | "batch-tpe") — resume refuses a
    /// checkpoint taken by a different proposer.
    pub algo: String,
    /// The EXACT space the run searched — full per-dim menus, not just a
    /// width. Stored configs are choice indices, meaningless against any
    /// other menus; resume compares this space's fingerprint against the
    /// new run's, and `search::project::SpaceProjection` uses the menus to
    /// remap the history when the spaces legitimately differ (a re-pruned
    /// search space).
    pub space: Space,
    /// Completed trials, in evaluation order.
    pub history: History,
    /// Proposer annealing rounds taken so far (k-means TPE; 0 for TPE).
    pub iter: usize,
    /// k-means warm-start centroids (decreasing; empty for TPE).
    pub centroids: Vec<f64>,
    /// RNG cursor at the round boundary.
    pub rng: RngState,
}

impl SearchCheckpoint {
    pub fn to_json(&self) -> Json {
        let configs: Vec<Json> =
            self.history.trials.iter().map(|t| config_to_json(&t.config)).collect();
        let values: Vec<f64> = self.history.trials.iter().map(|t| t.value).collect();
        let eval_secs: Vec<f64> =
            self.history.trials.iter().map(|t| t.eval_secs).collect();
        obj(vec![
            ("algo", Json::Str(self.algo.clone())),
            ("space", self.space.to_json()),
            // Redundant with `space` by construction, and VERIFIED against
            // it on load: a hand-edited space that kept a stale fingerprint
            // is rejected instead of silently resuming onto wrong menus.
            ("fingerprint", Json::Str(self.space.fingerprint())),
            (
                "history",
                obj(vec![
                    ("searcher", Json::Str(self.history.searcher.clone())),
                    ("configs", Json::Arr(configs)),
                    ("values", enc_f64_arr(&values)),
                    ("eval_secs", enc_f64_arr(&eval_secs)),
                ]),
            ),
            ("iter", Json::Num(self.iter as f64)),
            ("centroids", enc_f64_arr(&self.centroids)),
            ("rng", self.rng.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SearchCheckpoint> {
        let algo = j.req("algo")?.as_str().context("algo")?.to_string();
        let space = Space::from_json(j.req("space")?).context("checkpoint space")?;
        let fp = j.req("fingerprint")?.as_str().context("fingerprint")?;
        anyhow::ensure!(
            fp == space.fingerprint(),
            "checkpoint fingerprint '{fp}' does not match its own space ('{}'): the file \
             was edited or corrupted",
            space.fingerprint()
        );
        let h = j.req("history")?;
        let searcher = h.req("searcher")?.as_str().context("searcher")?.to_string();
        let configs: Vec<Config> = h
            .req("configs")?
            .as_arr()
            .context("configs")?
            .iter()
            .map(config_from_json)
            .collect::<anyhow::Result<_>>()?;
        let values = dec_f64_arr(h.req("values")?).context("values")?;
        let eval_secs = dec_f64_arr(h.req("eval_secs")?).context("eval_secs")?;
        anyhow::ensure!(
            configs.len() == values.len() && values.len() == eval_secs.len(),
            "checkpoint history arrays disagree: {} configs, {} values, {} timings",
            configs.len(),
            values.len(),
            eval_secs.len()
        );
        for (i, c) in configs.iter().enumerate() {
            anyhow::ensure!(
                space.validate(c),
                "checkpoint trial {i} ({c:?}) is invalid for the checkpoint's own \
                 {}-dim space",
                space.num_dims()
            );
        }
        let trials = configs
            .into_iter()
            .zip(values)
            .zip(eval_secs)
            .map(|((config, value), eval_secs)| Trial { config, value, eval_secs })
            .collect();
        Ok(SearchCheckpoint {
            algo,
            space,
            history: History { trials, searcher },
            iter: j.req("iter")?.as_usize().context("iter")?,
            centroids: dec_f64_arr(j.req("centroids")?).context("centroids")?,
            rng: RngState::from_json(j.req("rng")?)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_space() -> Space {
        use super::super::space::Dim;
        Space::new(vec![
            Dim::new("bits0", vec![8.0, 6.0, 4.0]),
            Dim::new("bits1", vec![4.0, 3.0, 2.0]),
            Dim::new("width0", vec![0.75, 1.0]),
        ])
    }

    fn sample_checkpoint() -> SearchCheckpoint {
        let mut history = History::new("batch-kmeans-tpe");
        history.push(vec![0, 2, 1], 0.75, 0.01);
        history.push(vec![1, 1, 1], f64::NEG_INFINITY, 0.02); // failed eval
        history.push(vec![2, 0, 0], -1.5, 0.0);
        let mut rng = Rng::new(1234);
        rng.next_u64();
        rng.gauss(); // leave a spare pending
        SearchCheckpoint {
            algo: "batch-kmeans-tpe".to_string(),
            space: sample_space(),
            history,
            iter: 5,
            centroids: vec![0.75, -0.4, -1.5],
            rng: RngState::of(&rng),
        }
    }

    #[test]
    fn serde_roundtrip_is_byte_identical() {
        let ck = sample_checkpoint();
        let text = ck.to_json().to_string_pretty();
        let back = SearchCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string_pretty(), text);
        assert_eq!(back.algo, ck.algo);
        assert_eq!(back.iter, 5);
        assert_eq!(back.centroids, ck.centroids);
        assert_eq!(back.history.len(), 3);
        assert_eq!(back.history.trials[1].value, f64::NEG_INFINITY);
        assert_eq!(back.history.trials[0].config, vec![0, 2, 1]);
    }

    #[test]
    fn rng_cursor_survives_serde_exactly() {
        let ck = sample_checkpoint();
        let back =
            SearchCheckpoint::from_json(&Json::parse(&ck.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back.rng, ck.rng);
        let mut a = ck.rng.to_rng();
        let mut b = back.rng.to_rng();
        assert_eq!(a.gauss(), b.gauss());
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn malformed_checkpoints_are_rejected() {
        let ck = sample_checkpoint();
        // Mismatched array lengths.
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(h)) = m.get_mut("history") {
                h.insert("values".into(), enc_f64_arr(&[1.0]));
            }
        }
        assert!(SearchCheckpoint::from_json(&j).unwrap_err().to_string().contains("disagree"));
        // A tampered space whose fingerprint was not updated is rejected —
        // the fingerprint is verified against the space it travels with.
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("space", sample_space().to_json());
            m.insert("fingerprint", Json::Str("0000000000000000".into()));
        }
        let err = SearchCheckpoint::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        // A trial whose index overruns its dim's menu is rejected.
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(h)) = m.get_mut("history") {
                if let Some(Json::Arr(cfgs)) = h.get_mut("configs") {
                    cfgs[0] = Json::Arr(vec![
                        Json::Num(9.0),
                        Json::Num(0.0),
                        Json::Num(0.0),
                    ]);
                }
            }
        }
        let err = SearchCheckpoint::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("invalid"), "{err}");
        // Bad rng word.
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(r)) = m.get_mut("rng") {
                r.insert("s".into(), Json::Arr(vec![Json::Str("zz".into()); 4]));
            }
        }
        assert!(SearchCheckpoint::from_json(&j).is_err());
    }
}
