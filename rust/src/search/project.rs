//! Config projection between overlapping search spaces — the machinery that
//! makes CROSS-SPACE resume safe and useful.
//!
//! The paper's search space is *produced* by Hessian-based pruning, so the
//! menus a leader searches are a function of sensitivity estimates that can
//! legitimately change between runs (or, with `--reprune-every`, within
//! one): a fresh trace estimate moves a layer across a cluster boundary and
//! its bit menu changes. A checkpoint stores choice INDICES; replaying them
//! against different menus silently reinterprets every trial (index 1 that
//! meant 6 bits now means 3) and corrupts the warm-started surrogates. The
//! fingerprint guard in `BatchSearcher::start` refuses that resume; this
//! module is the constructive half — [`SpaceProjection::between`] matches
//! dims by NAME and choices by VALUE, remapping each checkpointed trial onto
//! the new space:
//!
//! * a choice that survived pruning keeps its (re-indexed) slot exactly;
//! * a pruned-away choice is SNAPPED to the nearest surviving value
//!   ([`ProjectPolicy::Nearest`]) or the trial is DROPPED
//!   ([`ProjectPolicy::Strict`]);
//! * an old dim absent from the new space is marginalized out (the product
//!   Parzen simply loses that factor);
//! * a new dim absent from the old space is filled from the prior — a
//!   deterministic seeded sample, so projecting the same checkpoint twice
//!   yields byte-identical results.
//!
//! The per-trial outcomes are tallied in a [`ProjectionReport`]
//! (kept + snapped + dropped always sums to the checkpointed trial count)
//! that the leader logs before resuming.

use super::checkpoint::SearchCheckpoint;
use super::history::History;
use super::space::{Config, Space};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

/// What to do with a checkpointed trial whose choice was pruned away.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProjectPolicy {
    /// Snap the coordinate to the surviving choice with the nearest value
    /// (ties break to the lower index). Keeps the whole history — the
    /// snapped trials are approximate evidence, which is still far better
    /// than a cold start on flat DNN landscapes.
    Nearest,
    /// Drop any trial touching a pruned choice. The surviving history is
    /// exact — every kept trial's values are unchanged under the new menus.
    Strict,
}

impl ProjectPolicy {
    /// Parse a `--resume-project` setting.
    pub fn parse(s: &str) -> Option<ProjectPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "nearest" => Some(ProjectPolicy::Nearest),
            "strict" => Some(ProjectPolicy::Strict),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ProjectPolicy::Nearest => "nearest",
            ProjectPolicy::Strict => "strict",
        }
    }
}

/// Where one OLD choice lands in the new menu.
#[derive(Debug, Clone, Copy)]
struct ChoiceTarget {
    /// New index holding the bit-identical value, if the choice survived.
    exact: Option<usize>,
    /// New index with the nearest value (always defined — menus are
    /// non-empty; ties break to the lower index).
    nearest: usize,
}

/// Source of one NEW dim: the old dim it matched (by name) and where each
/// old choice lands.
#[derive(Debug, Clone)]
struct DimSource {
    old_dim: usize,
    /// Indexed by OLD choice index.
    targets: Vec<ChoiceTarget>,
}

/// Per-(new, matched) dim tallies for the report.
#[derive(Debug, Clone)]
pub struct DimReport {
    pub name: String,
    /// Trials whose coordinate in this dim was snapped (nearest policy).
    pub snapped: usize,
    /// Trials dropped because this dim's choice was pruned (strict policy;
    /// a trial failing in several dims counts in each).
    pub dropped: usize,
}

/// What happened to a projected history, trial by trial and dim by dim.
#[derive(Debug, Clone)]
pub struct ProjectionReport {
    pub policy: ProjectPolicy,
    /// Trials carried over with every coordinate exactly preserved.
    pub kept: usize,
    /// Trials carried over with at least one snapped (or prior-filled)
    /// coordinate.
    pub snapped: usize,
    /// Trials dropped (strict policy only).
    pub dropped: usize,
    pub per_dim: Vec<DimReport>,
    /// Old dims with no counterpart in the new space (marginalized out).
    pub dropped_dims: Vec<String>,
    /// New dims with no counterpart in the old space (prior-filled).
    pub new_dims: Vec<String>,
    pub old_fingerprint: String,
    pub new_fingerprint: String,
}

impl ProjectionReport {
    /// Invariant the acceptance tests pin: every checkpointed trial is
    /// accounted for exactly once.
    pub fn total(&self) -> usize {
        self.kept + self.snapped + self.dropped
    }

    /// Human-readable multi-line summary (the leader logs this on resume).
    pub fn render(&self) -> String {
        let mut s = format!(
            "[project] space {} -> {} ({} policy): {} kept, {} snapped, {} dropped \
             of {} trials",
            self.old_fingerprint,
            self.new_fingerprint,
            self.policy.name(),
            self.kept,
            self.snapped,
            self.dropped,
            self.total()
        );
        for d in &self.per_dim {
            if d.snapped > 0 || d.dropped > 0 {
                s.push_str(&format!(
                    "\n[project]   dim '{}': {} snapped, {} dropped",
                    d.name, d.snapped, d.dropped
                ));
            }
        }
        if !self.dropped_dims.is_empty() {
            s.push_str(&format!(
                "\n[project]   dims marginalized out: {:?}",
                self.dropped_dims
            ));
        }
        if !self.new_dims.is_empty() {
            s.push_str(&format!(
                "\n[project]   new dims filled from the prior: {:?}",
                self.new_dims
            ));
        }
        s
    }

    /// Structured encoding for the serve daemon's job journal (resume /
    /// warm-start / re-prune projections become replayable events, not
    /// just log lines).
    pub fn to_json(&self) -> Json {
        let dims = |names: &[String]| {
            Json::Arr(names.iter().map(|n| Json::Str(n.clone())).collect())
        };
        obj(vec![
            ("policy", Json::Str(self.policy.name().to_string())),
            ("kept", Json::Num(self.kept as f64)),
            ("snapped", Json::Num(self.snapped as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            (
                "per_dim",
                Json::Arr(
                    self.per_dim
                        .iter()
                        .map(|d| {
                            obj(vec![
                                ("name", Json::Str(d.name.clone())),
                                ("snapped", Json::Num(d.snapped as f64)),
                                ("dropped", Json::Num(d.dropped as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("dropped_dims", dims(&self.dropped_dims)),
            ("new_dims", dims(&self.new_dims)),
            ("old_fingerprint", Json::Str(self.old_fingerprint.clone())),
            ("new_fingerprint", Json::Str(self.new_fingerprint.clone())),
        ])
    }

    /// Inverse of [`to_json`](Self::to_json) — journal replay.
    pub fn from_json(j: &Json) -> anyhow::Result<ProjectionReport> {
        use anyhow::Context;
        let names = |k: &str| -> anyhow::Result<Vec<String>> {
            Ok(j.req(k)?
                .as_arr()
                .with_context(|| format!("'{k}' not an array"))?
                .iter()
                .filter_map(|v| v.as_str().map(str::to_string))
                .collect())
        };
        let policy_name = j.req("policy")?.as_str().context("policy")?;
        Ok(ProjectionReport {
            policy: ProjectPolicy::parse(policy_name)
                .with_context(|| format!("unknown projection policy '{policy_name}'"))?,
            kept: j.req("kept")?.as_usize().context("kept")?,
            snapped: j.req("snapped")?.as_usize().context("snapped")?,
            dropped: j.req("dropped")?.as_usize().context("dropped")?,
            per_dim: j
                .req("per_dim")?
                .as_arr()
                .context("per_dim")?
                .iter()
                .map(|d| {
                    Ok(DimReport {
                        name: d.req("name")?.as_str().context("dim name")?.to_string(),
                        snapped: d.req("snapped")?.as_usize().context("dim snapped")?,
                        dropped: d.req("dropped")?.as_usize().context("dim dropped")?,
                    })
                })
                .collect::<anyhow::Result<_>>()?,
            dropped_dims: names("dropped_dims")?,
            new_dims: names("new_dims")?,
            old_fingerprint: j
                .req("old_fingerprint")?
                .as_str()
                .context("old_fingerprint")?
                .to_string(),
            new_fingerprint: j
                .req("new_fingerprint")?
                .as_str()
                .context("new_fingerprint")?
                .to_string(),
        })
    }
}

/// A projected checkpoint plus the per-trial map the caller needs to keep
/// any history-aligned side data (the leader's `EvalRecord` log) in sync.
#[derive(Debug, Clone)]
pub struct ProjectionOutcome {
    /// The checkpoint rewritten onto the new space: remapped history, same
    /// annealing cursor, finite warm centroids, same RNG cursor.
    pub search: SearchCheckpoint,
    /// Per OLD trial, in order: its projected config (`None` = dropped).
    pub map: Vec<Option<Config>>,
    pub report: ProjectionReport,
}

/// A dim-name/choice-value matching between two spaces (see module docs).
#[derive(Debug, Clone)]
pub struct SpaceProjection {
    /// Per NEW dim: its old-space source (`None` = brand-new dim).
    sources: Vec<Option<DimSource>>,
    new_dim_names: Vec<String>,
    dropped_dims: Vec<String>,
    new_dims: Vec<String>,
    old_fingerprint: String,
    new_fingerprint: String,
    /// Seed for deterministic prior fills, derived from both fingerprints.
    fill_seed: u64,
}

impl SpaceProjection {
    /// Match `old` against `new`: dims pair up by name, choices by value.
    /// O(dims) in the dimension count — a linear name scan per dim would
    /// be quadratic, a real stall at the thousand-layer spaces the big
    /// hello cap exists for (menus themselves are tiny, so the per-choice
    /// scans stay negligible).
    pub fn between(old: &Space, new: &Space) -> SpaceProjection {
        let mut old_by_name =
            std::collections::HashMap::with_capacity(old.num_dims());
        for (i, od) in old.dims.iter().enumerate() {
            // First occurrence wins, matching what a linear scan would do
            // (duplicate names don't occur in built spaces, but stay
            // deterministic if they ever did).
            old_by_name.entry(od.name.as_str()).or_insert(i);
        }
        let mut matched = vec![false; old.num_dims()];
        let mut sources = Vec::with_capacity(new.num_dims());
        let mut new_dims = Vec::new();
        for nd in &new.dims {
            let Some(&old_dim) = old_by_name.get(nd.name.as_str()) else {
                new_dims.push(nd.name.clone());
                sources.push(None);
                continue;
            };
            matched[old_dim] = true;
            let targets = old.dims[old_dim]
                .choices
                .iter()
                .map(|&v| {
                    let exact = nd.choices.iter().position(|&c| c == v);
                    let nearest = nd
                        .choices
                        .iter()
                        .enumerate()
                        .min_by(|(_, a), (_, b)| {
                            (*a - v).abs().total_cmp(&(*b - v).abs())
                        })
                        .map(|(i, _)| i)
                        .expect("dims are never empty");
                    ChoiceTarget { exact, nearest }
                })
                .collect();
            sources.push(Some(DimSource { old_dim, targets }));
        }
        let dropped_dims = old
            .dims
            .iter()
            .enumerate()
            .filter(|(i, _)| !matched[*i])
            .map(|(_, d)| d.name.clone())
            .collect();
        let (old_fp, new_fp) = (old.fingerprint(), new.fingerprint());
        let fill_seed = u64::from_str_radix(&old_fp, 16).unwrap_or(0)
            ^ u64::from_str_radix(&new_fp, 16).unwrap_or(0).rotate_left(17);
        SpaceProjection {
            sources,
            new_dim_names: new.dims.iter().map(|d| d.name.clone()).collect(),
            dropped_dims,
            new_dims,
            old_fingerprint: old_fp,
            new_fingerprint: new_fp,
            fill_seed,
        }
    }

    /// How many NEW dims found a same-name source in the old space — the
    /// REAL-evidence overlap. The warehouse warm-start ranks candidate
    /// histories by this and refuses to seed when it is zero: projecting
    /// across disjoint spaces is pure prior fill, i.e. noise dressed up
    /// as evidence.
    pub fn matched_dims(&self) -> usize {
        self.sources.iter().filter(|s| s.is_some()).count()
    }

    /// Project one config. `Some((config, inexact))` carries the new
    /// config and whether any coordinate was snapped or prior-filled;
    /// `None` means the trial is dropped under the strict policy. `fill`
    /// draws prior samples for brand-new dims.
    fn project_config(
        &self,
        old: &Config,
        policy: ProjectPolicy,
        fill: &mut Rng,
        new_space: &Space,
        snapped_dims: &mut [bool],
        dropped_dims: &mut [bool],
    ) -> Option<(Config, bool)> {
        let mut out = Vec::with_capacity(self.sources.len());
        let mut inexact = false;
        let mut keep = true;
        for (d, src) in self.sources.iter().enumerate() {
            let Some(src) = src else {
                // Brand-new dim: the checkpoint holds no evidence — fill
                // from the (uniform) prior. Drawn even for trials that end
                // up dropped, so the fill stream is policy-independent.
                out.push(fill.below(new_space.dims[d].k()));
                inexact = true;
                continue;
            };
            let t = src.targets[old[src.old_dim]];
            match (t.exact, policy) {
                (Some(i), _) => out.push(i),
                (None, ProjectPolicy::Nearest) => {
                    out.push(t.nearest);
                    inexact = true;
                    snapped_dims[d] = true;
                }
                (None, ProjectPolicy::Strict) => {
                    dropped_dims[d] = true;
                    keep = false;
                    // Keep scanning so the report blames EVERY offending
                    // dim, not just the first.
                    out.push(t.nearest);
                }
            }
        }
        if keep {
            Some((out, inexact))
        } else {
            None
        }
    }

    /// Project a trial list. Returns the per-trial map (`None` = dropped)
    /// and the tally. `kept + snapped + dropped == configs.len()` always.
    pub fn project_trials(
        &self,
        configs: &[Config],
        new_space: &Space,
        policy: ProjectPolicy,
    ) -> (Vec<Option<Config>>, ProjectionReport) {
        let nd = self.sources.len();
        let mut fill = Rng::new(self.fill_seed ^ 0x9E37_79B9_7F4A_7C15);
        let mut per_dim: Vec<DimReport> = self
            .new_dim_names
            .iter()
            .map(|n| DimReport { name: n.clone(), snapped: 0, dropped: 0 })
            .collect();
        let (mut kept, mut snapped, mut dropped) = (0usize, 0usize, 0usize);
        let mut map = Vec::with_capacity(configs.len());
        for c in configs {
            let mut sd = vec![false; nd];
            let mut dd = vec![false; nd];
            match self.project_config(c, policy, &mut fill, new_space, &mut sd, &mut dd) {
                Some((nc, inexact)) => {
                    debug_assert!(new_space.validate(&nc), "projected config invalid");
                    if inexact {
                        snapped += 1;
                    } else {
                        kept += 1;
                    }
                    for (d, &s) in sd.iter().enumerate() {
                        if s {
                            per_dim[d].snapped += 1;
                        }
                    }
                    map.push(Some(nc));
                }
                None => {
                    dropped += 1;
                    for (d, &x) in dd.iter().enumerate() {
                        if x {
                            per_dim[d].dropped += 1;
                        }
                    }
                    map.push(None);
                }
            }
        }
        let report = ProjectionReport {
            policy,
            kept,
            snapped,
            dropped,
            per_dim,
            dropped_dims: self.dropped_dims.clone(),
            new_dims: self.new_dims.clone(),
            old_fingerprint: self.old_fingerprint.clone(),
            new_fingerprint: self.new_fingerprint.clone(),
        };
        (map, report)
    }

    /// Project a whole [`SearchCheckpoint`] onto `new_space`. The surviving
    /// trials keep their values and timings (a snapped config's measured
    /// value is approximate evidence — the surrogates re-fit from it, they
    /// never re-trust it as exact); the annealing round counter and the RNG
    /// cursor carry over unchanged, and the warm centroids are filtered to
    /// finite values (failed-trial sentinels must not disable the warm
    /// start downstream).
    pub fn project_checkpoint(
        &self,
        ck: &SearchCheckpoint,
        new_space: Space,
        policy: ProjectPolicy,
    ) -> ProjectionOutcome {
        let configs: Vec<Config> =
            ck.history.trials.iter().map(|t| t.config.clone()).collect();
        let (map, report) = self.project_trials(&configs, &new_space, policy);
        let mut history = History::new(&ck.history.searcher);
        for (t, m) in ck.history.trials.iter().zip(&map) {
            if let Some(nc) = m {
                history.push(nc.clone(), t.value, t.eval_secs);
            }
        }
        let centroids: Vec<f64> =
            ck.centroids.iter().copied().filter(|c| c.is_finite()).collect();
        let search = SearchCheckpoint {
            algo: ck.algo.clone(),
            space: new_space,
            history,
            iter: ck.iter,
            centroids,
            rng: ck.rng.clone(),
        };
        ProjectionOutcome { search, map, report }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::checkpoint::RngState;
    use crate::search::space::Dim;

    fn old_space() -> Space {
        Space::new(vec![
            Dim::new("bits:a", vec![8.0, 6.0, 4.0, 3.0, 2.0]),
            Dim::new("bits:b", vec![6.0, 4.0, 3.0]),
            Dim::new("width:w", vec![0.75, 1.0, 1.25]),
        ])
    }

    /// bits:a pruned to its top half, bits:b re-windowed, width unchanged.
    fn repruned_space() -> Space {
        Space::new(vec![
            Dim::new("bits:a", vec![8.0, 6.0]),
            Dim::new("bits:b", vec![4.0, 3.0, 2.0]),
            Dim::new("width:w", vec![0.75, 1.0, 1.25]),
        ])
    }

    fn ck_of(space: Space, trials: Vec<(Config, f64)>) -> SearchCheckpoint {
        let mut history = History::new("batch-kmeans-tpe");
        for (c, v) in trials {
            history.push(c, v, 0.01);
        }
        SearchCheckpoint {
            algo: "batch-kmeans-tpe".to_string(),
            space,
            history,
            iter: 4,
            centroids: vec![0.9, 0.1],
            rng: RngState::of(&Rng::new(5)),
        }
    }

    #[test]
    fn identical_spaces_keep_everything_exactly() {
        let proj = SpaceProjection::between(&old_space(), &old_space());
        let configs = vec![vec![0, 0, 0], vec![4, 2, 2], vec![2, 1, 1]];
        for policy in [ProjectPolicy::Nearest, ProjectPolicy::Strict] {
            let (map, rep) = proj.project_trials(&configs, &old_space(), policy);
            assert_eq!(rep.kept, 3);
            assert_eq!(rep.snapped + rep.dropped, 0);
            assert_eq!(rep.total(), configs.len());
            for (m, c) in map.iter().zip(&configs) {
                assert_eq!(m.as_ref().unwrap(), c);
            }
        }
    }

    #[test]
    fn surviving_choices_reindex_and_pruned_ones_snap_or_drop() {
        let (old, new) = (old_space(), repruned_space());
        let proj = SpaceProjection::between(&old, &new);
        // bits:a=6.0 (old idx 1 -> new idx 1), bits:b=4.0 (old 1 -> new 0),
        // width 1.0 (unchanged idx 1): fully exact.
        // bits:a=2.0 was pruned; nearest survivor is 6.0 (new idx 1).
        // bits:b=6.0 was pruned; nearest survivor is 4.0 (new idx 0).
        let configs = vec![vec![1, 1, 1], vec![4, 0, 2]];
        let (map, rep) =
            proj.project_trials(&configs, &new, ProjectPolicy::Nearest);
        assert_eq!((rep.kept, rep.snapped, rep.dropped), (1, 1, 0));
        assert_eq!(map[0].as_ref().unwrap(), &vec![1, 0, 1]);
        assert_eq!(map[1].as_ref().unwrap(), &vec![1, 0, 2]);
        assert_eq!(rep.per_dim[0].snapped, 1);
        assert_eq!(rep.per_dim[1].snapped, 1);

        let (map, rep) = proj.project_trials(&configs, &new, ProjectPolicy::Strict);
        assert_eq!((rep.kept, rep.snapped, rep.dropped), (1, 0, 1));
        assert_eq!(map[0].as_ref().unwrap(), &vec![1, 0, 1]);
        assert!(map[1].is_none());
        // Strict blames EVERY offending dim of the dropped trial.
        assert_eq!(rep.per_dim[0].dropped, 1);
        assert_eq!(rep.per_dim[1].dropped, 1);
        assert_eq!(rep.total(), configs.len());
    }

    #[test]
    fn entirely_changed_menu_drops_all_under_strict_snaps_all_under_nearest() {
        // Satellite edge case: bits:b's menu changed COMPLETELY.
        let old = Space::new(vec![
            Dim::new("bits:a", vec![8.0, 6.0]),
            Dim::new("bits:b", vec![8.0, 6.0]),
        ]);
        let new = Space::new(vec![
            Dim::new("bits:a", vec![8.0, 6.0]),
            Dim::new("bits:b", vec![3.0, 2.0]),
        ]);
        let proj = SpaceProjection::between(&old, &new);
        let configs = vec![vec![0, 0], vec![0, 1], vec![1, 0], vec![1, 1]];
        let (map, rep) = proj.project_trials(&configs, &new, ProjectPolicy::Strict);
        assert_eq!((rep.kept, rep.snapped, rep.dropped), (0, 0, 4));
        assert!(map.iter().all(|m| m.is_none()));
        let (map, rep) = proj.project_trials(&configs, &new, ProjectPolicy::Nearest);
        assert_eq!((rep.kept, rep.snapped, rep.dropped), (0, 4, 0));
        // Every old bits:b value is closest to the new menu's 3.0 (idx 0).
        for m in &map {
            assert_eq!(m.as_ref().unwrap()[1], 0);
        }
        let rendered = rep.render();
        assert!(rendered.contains("4 snapped"), "{rendered}");
    }

    #[test]
    fn dropped_dims_marginalize_and_new_dims_fill_deterministically() {
        let old = Space::new(vec![
            Dim::new("bits:gone", vec![8.0, 6.0]),
            Dim::new("bits:kept", vec![6.0, 4.0, 3.0]),
        ]);
        let new = Space::new(vec![
            Dim::new("bits:kept", vec![6.0, 4.0, 3.0]),
            Dim::new("bits:fresh", vec![4.0, 3.0, 2.0]),
        ]);
        let proj = SpaceProjection::between(&old, &new);
        assert_eq!(proj.matched_dims(), 1, "only bits:kept is shared");
        assert_eq!(SpaceProjection::between(&old, &old).matched_dims(), 2);
        let configs = vec![vec![0, 2], vec![1, 0], vec![1, 1]];
        let (map1, rep) = proj.project_trials(&configs, &new, ProjectPolicy::Strict);
        // Marginalizing an old dim never drops trials; the prior fill makes
        // every carried trial inexact, so they count as snapped.
        assert_eq!((rep.kept, rep.snapped, rep.dropped), (0, 3, 0));
        assert_eq!(rep.dropped_dims, vec!["bits:gone".to_string()]);
        assert_eq!(rep.new_dims, vec!["bits:fresh".to_string()]);
        for (m, c) in map1.iter().zip(&configs) {
            let m = m.as_ref().unwrap();
            assert_eq!(m[0], c[1], "kept dim must carry its old coordinate");
            assert!(m[1] < 3, "prior fill out of range");
        }
        // Deterministic: a second projection is byte-identical.
        let proj2 = SpaceProjection::between(&old, &new);
        let (map2, _) = proj2.project_trials(&configs, &new, ProjectPolicy::Strict);
        assert_eq!(map1, map2);
    }

    #[test]
    fn nearest_tie_breaks_to_the_lower_index() {
        let old = Space::new(vec![Dim::new("d", vec![5.0])]);
        let new = Space::new(vec![Dim::new("d", vec![4.0, 6.0])]);
        let proj = SpaceProjection::between(&old, &new);
        let (map, _) =
            proj.project_trials(&[vec![0]], &new, ProjectPolicy::Nearest);
        // |5-4| == |5-6|: the lower index wins, deterministically.
        assert_eq!(map[0].as_ref().unwrap(), &vec![0]);
    }

    #[test]
    fn checkpoint_projection_keeps_values_and_sanitizes_centroids() {
        let (old, new) = (old_space(), repruned_space());
        let mut ck = ck_of(
            old.clone(),
            vec![
                (vec![1, 1, 1], 0.9),
                (vec![4, 0, 2], f64::NEG_INFINITY), // failed eval, snapped
                (vec![0, 2, 0], 0.4),
            ],
        );
        // A failure sentinel that leaked into the warm centroids must not
        // survive projection (it would silently disable the Lloyd warm
        // start after restore).
        ck.centroids = vec![0.9, f64::NEG_INFINITY, 0.1];
        let proj = SpaceProjection::between(&old, &new);
        let out = proj.project_checkpoint(&ck, new.clone(), ProjectPolicy::Nearest);
        assert_eq!(out.report.total(), 3);
        assert_eq!(out.search.history.len(), 3);
        assert_eq!(out.search.space.fingerprint(), new.fingerprint());
        assert_eq!(out.search.iter, ck.iter);
        assert_eq!(out.search.rng, ck.rng);
        assert_eq!(out.search.centroids, vec![0.9, 0.1]);
        // Values ride along untouched — including the -inf failure.
        assert_eq!(out.search.history.trials[0].value, 0.9);
        assert_eq!(out.search.history.trials[1].value, f64::NEG_INFINITY);
        for t in &out.search.history.trials {
            assert!(new.validate(&t.config), "projected trial invalid: {:?}", t.config);
        }
        // The map aligns with the original trial order for side-data
        // (EvalRecord) projection.
        assert_eq!(out.map.len(), 3);
        assert_eq!(
            out.map[0].as_ref().unwrap(),
            &out.search.history.trials[0].config
        );
    }

    #[test]
    fn projected_histories_restore_into_both_surrogate_states() {
        use crate::search::kmeans_tpe::{KmeansTpeParams, KmeansTpeState};
        use crate::search::tpe::{TpeParams, TpeState};
        let (old, new) = (old_space(), repruned_space());
        let ck = ck_of(
            old.clone(),
            vec![
                (vec![0, 0, 0], 0.7),
                (vec![4, 2, 2], f64::NEG_INFINITY),
                (vec![2, 1, 1], 0.2),
            ],
        );
        let proj = SpaceProjection::between(&old, &new);
        let out = proj.project_checkpoint(&ck, new.clone(), ProjectPolicy::Nearest);
        let configs: Vec<Config> =
            out.search.history.trials.iter().map(|t| t.config.clone()).collect();
        let values: Vec<f64> =
            out.search.history.trials.iter().map(|t| t.value).collect();
        let mut km = KmeansTpeState::restore(
            KmeansTpeParams::default(),
            new.clone(),
            configs.clone(),
            values.clone(),
            out.search.iter,
            out.search.centroids.clone(),
        );
        let mut rng = Rng::new(3);
        // Proposals off the projected warm start stay inside the new space.
        for _ in 0..4 {
            assert!(new.validate(&km.propose(&mut rng)));
        }
        let mut tpe =
            TpeState::restore(TpeParams::default(), new.clone(), configs, values);
        for _ in 0..4 {
            assert!(new.validate(&tpe.propose(&mut rng)));
        }
    }

    #[test]
    fn projection_report_json_round_trip() {
        let report = ProjectionReport {
            policy: ProjectPolicy::Strict,
            kept: 5,
            snapped: 2,
            dropped: 1,
            per_dim: vec![
                DimReport { name: "bits:a".into(), snapped: 2, dropped: 1 },
                DimReport { name: "width:w".into(), snapped: 0, dropped: 0 },
            ],
            dropped_dims: vec!["bits:gone".into()],
            new_dims: vec!["bits:new".into()],
            old_fingerprint: "fp-old".into(),
            new_fingerprint: "fp-new".into(),
        };
        let back = ProjectionReport::from_json(&report.to_json()).unwrap();
        assert_eq!(back.to_json(), report.to_json());
        assert_eq!(back.policy, ProjectPolicy::Strict);
        assert_eq!(back.total(), report.total());
        assert_eq!(back.render(), report.render());
    }
}
