//! Trial history: configs, objective values, timings, convergence curves.

use super::space::Config;
use crate::util::stats;

#[derive(Debug, Clone)]
pub struct Trial {
    pub config: Config,
    pub value: f64,
    /// Wall-clock seconds spent evaluating this trial.
    pub eval_secs: f64,
}

#[derive(Debug, Clone, Default)]
pub struct History {
    pub trials: Vec<Trial>,
    pub searcher: String,
}

impl History {
    pub fn new(searcher: &str) -> History {
        History { trials: Vec::new(), searcher: searcher.to_string() }
    }

    pub fn push(&mut self, config: Config, value: f64, eval_secs: f64) {
        self.trials.push(Trial { config, value, eval_secs });
    }

    pub fn len(&self) -> usize {
        self.trials.len()
    }

    pub fn is_empty(&self) -> bool {
        self.trials.is_empty()
    }

    pub fn values(&self) -> Vec<f64> {
        self.trials.iter().map(|t| t.value).collect()
    }

    pub fn best(&self) -> Option<&Trial> {
        self.trials
            .iter()
            .max_by(|a, b| a.value.partial_cmp(&b.value).unwrap())
    }

    /// Best-so-far curve (for Fig. 3 convergence plots).
    pub fn convergence_curve(&self) -> Vec<f64> {
        stats::cummax(&self.values())
    }

    /// Number of evaluations needed to reach `frac` of the final best
    /// (the paper's "2-3x fewer evaluations" convergence metric).
    pub fn evals_to_reach(&self, target: f64) -> Option<usize> {
        stats::first_reach(&self.values(), target, 1e-12).map(|i| i + 1)
    }

    pub fn total_eval_secs(&self) -> f64 {
        self.trials.iter().map(|t| t.eval_secs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_and_curve() {
        let mut h = History::new("test");
        h.push(vec![0], 0.1, 1.0);
        h.push(vec![1], 0.5, 1.0);
        h.push(vec![2], 0.3, 1.0);
        assert_eq!(h.best().unwrap().value, 0.5);
        assert_eq!(h.convergence_curve(), vec![0.1, 0.5, 0.5]);
        assert_eq!(h.evals_to_reach(0.5), Some(2));
        assert_eq!(h.evals_to_reach(0.9), None);
        assert_eq!(h.total_eval_secs(), 3.0);
    }
}
