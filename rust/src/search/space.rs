//! Categorical search space.
//!
//! Every dimension is an ordered list of numeric choices (bit-widths, width
//! multipliers, tree counts, learning rates...). TPE over quantized grids is
//! exact for the Parzen ratio and matches the paper's spaces, which are all
//! finite sets (B per cluster, S = {0.75..1.25}).

use crate::util::rng::Rng;

/// A configuration: one choice index per dimension.
pub type Config = Vec<usize>;

#[derive(Debug, Clone)]
pub struct Dim {
    pub name: String,
    /// Numeric value of each choice (ordered as presented to the searcher).
    pub choices: Vec<f64>,
}

impl Dim {
    pub fn new(name: impl Into<String>, choices: Vec<f64>) -> Dim {
        let d = Dim { name: name.into(), choices };
        assert!(!d.choices.is_empty(), "dim {} has no choices", d.name);
        d
    }

    pub fn k(&self) -> usize {
        self.choices.len()
    }
}

#[derive(Debug, Clone)]
pub struct Space {
    pub dims: Vec<Dim>,
}

impl Space {
    pub fn new(dims: Vec<Dim>) -> Space {
        Space { dims }
    }

    pub fn num_dims(&self) -> usize {
        self.dims.len()
    }

    /// Total number of configurations (saturating).
    pub fn cardinality(&self) -> u128 {
        self.dims.iter().fold(1u128, |acc, d| acc.saturating_mul(d.k() as u128))
    }

    pub fn sample(&self, rng: &mut Rng) -> Config {
        self.dims.iter().map(|d| rng.below(d.k())).collect()
    }

    /// Decode a config to the numeric value per dimension.
    pub fn values(&self, config: &Config) -> Vec<f64> {
        config
            .iter()
            .zip(&self.dims)
            .map(|(&c, d)| d.choices[c])
            .collect()
    }

    pub fn validate(&self, config: &Config) -> bool {
        config.len() == self.dims.len()
            && config.iter().zip(&self.dims).all(|(&c, d)| c < d.k())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_no_shrink;

    fn space() -> Space {
        Space::new(vec![
            Dim::new("bits0", vec![8.0, 6.0]),
            Dim::new("bits1", vec![4.0, 3.0, 2.0]),
            Dim::new("width0", vec![0.75, 0.875, 1.0, 1.125, 1.25]),
        ])
    }

    #[test]
    fn cardinality() {
        assert_eq!(space().cardinality(), 2 * 3 * 5);
    }

    #[test]
    fn decode() {
        let s = space();
        assert_eq!(s.values(&vec![1, 2, 0]), vec![6.0, 2.0, 0.75]);
    }

    #[test]
    fn prop_samples_valid() {
        let s = space();
        check_no_shrink("space-sample-valid", 256, |r| s.sample(r), |c| s.validate(c));
    }
}
