//! Categorical search space.
//!
//! Every dimension is an ordered list of numeric choices (bit-widths, width
//! multipliers, tree counts, learning rates...). TPE over quantized grids is
//! exact for the Parzen ratio and matches the paper's spaces, which are all
//! finite sets (B per cluster, S = {0.75..1.25}).

use crate::util::json::{arr_f64, obj, Json};
use crate::util::rng::Rng;

/// A configuration: one choice index per dimension.
pub type Config = Vec<usize>;

/// Wire/checkpoint encoding of a config: a plain index array.
pub fn config_to_json(config: &Config) -> Json {
    Json::Arr(config.iter().map(|&c| Json::Num(c as f64)).collect())
}

pub fn config_from_json(j: &Json) -> anyhow::Result<Config> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("config must be an array"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("config entries must be indices")))
        .collect()
}

#[derive(Debug, Clone)]
pub struct Dim {
    pub name: String,
    /// Numeric value of each choice (ordered as presented to the searcher).
    pub choices: Vec<f64>,
}

impl Dim {
    pub fn new(name: impl Into<String>, choices: Vec<f64>) -> Dim {
        let d = Dim { name: name.into(), choices };
        assert!(!d.choices.is_empty(), "dim {} has no choices", d.name);
        d
    }

    pub fn k(&self) -> usize {
        self.choices.len()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("choices", arr_f64(&self.choices)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Dim> {
        let name = j.req("name")?.as_str().ok_or_else(|| anyhow::anyhow!("dim name"))?;
        let choices: Vec<f64> = j
            .req("choices")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("dim choices"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("dim choice must be numeric")))
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(!choices.is_empty(), "dim '{name}' has no choices");
        Ok(Dim { name: name.to_string(), choices })
    }
}

#[derive(Debug, Clone)]
pub struct Space {
    pub dims: Vec<Dim>,
}

impl Space {
    pub fn new(dims: Vec<Dim>) -> Space {
        Space { dims }
    }

    pub fn num_dims(&self) -> usize {
        self.dims.len()
    }

    /// Total number of configurations (saturating).
    pub fn cardinality(&self) -> u128 {
        self.dims.iter().fold(1u128, |acc, d| acc.saturating_mul(d.k() as u128))
    }

    pub fn sample(&self, rng: &mut Rng) -> Config {
        self.dims.iter().map(|d| rng.below(d.k())).collect()
    }

    /// Decode a config to the numeric value per dimension.
    pub fn values(&self, config: &Config) -> Vec<f64> {
        config
            .iter()
            .zip(&self.dims)
            .map(|(&c, d)| d.choices[c])
            .collect()
    }

    pub fn validate(&self, config: &Config) -> bool {
        config.len() == self.dims.len()
            && config.iter().zip(&self.dims).all(|(&c, d)| c < d.k())
    }

    /// Content fingerprint of the space: FNV-1a over every dim's name and
    /// choice values (length-prefixed, like the pretrained-snapshot digest).
    /// Two spaces fingerprint equal iff they present the SAME menus in the
    /// same order — the property checkpoint resume needs, because stored
    /// configs are choice INDICES and only mean anything against the exact
    /// menus they were drawn from. A dim-count check cannot see a re-pruned
    /// menu of the same width; this can.
    pub fn fingerprint(&self) -> String {
        let mut h = crate::util::Fnv1a::new();
        for d in &self.dims {
            h.write_u64(d.name.len() as u64);
            h.write(d.name.as_bytes());
            h.write_u64(d.choices.len() as u64);
            for &c in &d.choices {
                h.write_u64(c.to_bits());
            }
        }
        h.hex()
    }

    /// Wire/checkpoint encoding: the full menu per dimension, so a worker
    /// rebuilds the *pruned* space the leader searched, not the default.
    pub fn to_json(&self) -> Json {
        obj(vec![(
            "dims",
            Json::Arr(self.dims.iter().map(|d| d.to_json()).collect()),
        )])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Space> {
        let dims: Vec<Dim> = j
            .req("dims")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("space dims must be an array"))?
            .iter()
            .map(Dim::from_json)
            .collect::<anyhow::Result<_>>()?;
        Ok(Space { dims })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_no_shrink;

    fn space() -> Space {
        Space::new(vec![
            Dim::new("bits0", vec![8.0, 6.0]),
            Dim::new("bits1", vec![4.0, 3.0, 2.0]),
            Dim::new("width0", vec![0.75, 0.875, 1.0, 1.125, 1.25]),
        ])
    }

    #[test]
    fn cardinality() {
        assert_eq!(space().cardinality(), 2 * 3 * 5);
    }

    #[test]
    fn decode() {
        let s = space();
        assert_eq!(s.values(&vec![1, 2, 0]), vec![6.0, 2.0, 0.75]);
    }

    #[test]
    fn serde_roundtrip_is_byte_identical() {
        let s = space();
        let text = s.to_json().to_string_pretty();
        let back = Space::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string_pretty(), text);
        assert_eq!(back.num_dims(), s.num_dims());
        assert_eq!(back.dims[2].choices, s.dims[2].choices);
        assert_eq!(back.dims[0].name, "bits0");

        let c: Config = vec![1, 2, 4];
        let ctext = config_to_json(&c).to_string_compact();
        let cback =
            config_from_json(&crate::util::json::Json::parse(&ctext).unwrap()).unwrap();
        assert_eq!(cback, c);
        assert_eq!(config_to_json(&cback).to_string_compact(), ctext);
        // Malformed configs are rejected, not coerced.
        assert!(config_from_json(&crate::util::json::Json::parse("[1,\"x\"]").unwrap())
            .is_err());
    }

    #[test]
    fn fingerprint_sees_menu_values_not_just_shape() {
        let s = space();
        assert_eq!(s.fingerprint(), space().fingerprint());
        assert_eq!(s.fingerprint().len(), 16);
        // Same dim count and widths, ONE choice value changed: different
        // fingerprint — exactly the skew the dim-count resume guard missed.
        let mut repruned = space();
        repruned.dims[1].choices = vec![4.0, 3.0, 8.0];
        assert_ne!(s.fingerprint(), repruned.fingerprint());
        // A renamed dim changes it too (projection matches dims by name).
        let mut renamed = space();
        renamed.dims[0].name = "bits9".to_string();
        assert_ne!(s.fingerprint(), renamed.fingerprint());
        // Length prefixes keep boundaries honest: moving a choice across a
        // dim boundary must not collide.
        let a = Space::new(vec![Dim::new("a", vec![1.0, 2.0]), Dim::new("b", vec![3.0])]);
        let b = Space::new(vec![Dim::new("a", vec![1.0]), Dim::new("b", vec![2.0, 3.0])]);
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn prop_samples_valid() {
        let s = space();
        check_no_shrink("space-sample-valid", 256, |r| s.sample(r), |c| s.validate(c));
    }
}
