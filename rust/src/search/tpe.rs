//! Vanilla tree-structured Parzen estimator (Bergstra et al., 2011) — the
//! baseline the paper's k-means TPE is measured against (Fig. 3).
//!
//! Single quantile threshold: after n0 random startup trials, split observed
//! objective values at the γ-quantile; l(x) fits the top γ fraction, g(x)
//! the rest; propose argmax l/g among candidates sampled from l.

use super::history::History;
use super::parzen::{propose, Parzen};
use super::space::Config;
use super::{Objective, Searcher};
use crate::util::rng::Rng;
use crate::util::Timer;

#[derive(Debug, Clone, Copy)]
pub struct TpeParams {
    /// Random startup trials before the surrogates are built.
    pub n_startup: usize,
    /// Top quantile treated as desirable (paper/HyperOpt default 0.25).
    pub gamma: f64,
    /// Candidates drawn from l(x) per proposal.
    pub n_candidates: usize,
    pub prior_weight: f64,
    pub seed: u64,
}

impl Default for TpeParams {
    fn default() -> Self {
        TpeParams { n_startup: 20, gamma: 0.25, n_candidates: 24, prior_weight: 1.0, seed: 0 }
    }
}

pub struct Tpe {
    pub params: TpeParams,
}

impl Tpe {
    pub fn new(params: TpeParams) -> Tpe {
        Tpe { params }
    }
}

impl Searcher for Tpe {
    fn name(&self) -> &'static str {
        "tpe"
    }

    fn run(&mut self, obj: &mut dyn Objective, budget: usize) -> History {
        let mut rng = Rng::new(self.params.seed ^ 0x79E);
        let mut hist = History::new(self.name());
        let space = obj.space().clone();

        for i in 0..budget {
            let config: Config = if i < self.params.n_startup {
                space.sample(&mut rng)
            } else {
                // Split at the gamma quantile (maximization: top gamma are
                // desirable).
                let mut order: Vec<usize> = (0..hist.len()).collect();
                order.sort_by(|&a, &b| {
                    hist.trials[b]
                        .value
                        .partial_cmp(&hist.trials[a].value)
                        .unwrap()
                });
                let n_top = ((hist.len() as f64) * self.params.gamma)
                    .ceil()
                    .max(1.0) as usize;
                let top: Vec<&Config> =
                    order[..n_top].iter().map(|&i| &hist.trials[i].config).collect();
                let rest: Vec<&Config> =
                    order[n_top..].iter().map(|&i| &hist.trials[i].config).collect();
                let l = Parzen::fit(&space, &top, self.params.prior_weight);
                let g = Parzen::fit(&space, &rest, self.params.prior_weight);
                propose(&l, &g, &mut rng, self.params.n_candidates)
            };
            let t = Timer::start();
            let value = obj.eval(&config);
            hist.push(config, value, t.secs());
        }
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::space::{Dim, Space};

    /// Separable synthetic objective: value = sum of per-dim scores, best at
    /// choice 0 everywhere.
    pub struct Separable {
        space: Space,
    }

    impl Separable {
        pub fn new(dims: usize, k: usize) -> Separable {
            let space = Space::new(
                (0..dims)
                    .map(|d| {
                        Dim::new(format!("d{d}"), (0..k).map(|c| c as f64).collect())
                    })
                    .collect(),
            );
            Separable { space }
        }
    }

    impl Objective for Separable {
        fn space(&self) -> &Space {
            &self.space
        }

        fn eval(&mut self, config: &Config) -> f64 {
            -(config.iter().map(|&c| c as f64).sum::<f64>())
        }
    }

    #[test]
    fn beats_random_on_separable() {
        // Statistical comparison over seeds (single runs are noisy).
        let budget = 60;
        let seeds = 0..8u64;
        let mut tpe_sum = 0.0;
        let mut rand_sum = 0.0;
        for seed in seeds {
            let mut obj = Separable::new(8, 4);
            let mut tpe =
                Tpe::new(TpeParams { n_startup: 15, seed, ..Default::default() });
            tpe_sum += tpe.run(&mut obj, budget).best().unwrap().value;

            let mut rng = Rng::new(seed ^ 0x5EED);
            let mut obj2 = Separable::new(8, 4);
            let space = obj2.space().clone();
            rand_sum += (0..budget)
                .map(|_| {
                    let c = space.sample(&mut rng);
                    obj2.eval(&c)
                })
                .fold(f64::NEG_INFINITY, f64::max);
        }
        assert!(
            tpe_sum >= rand_sum,
            "tpe mean {} vs random mean {}",
            tpe_sum / 8.0,
            rand_sum / 8.0
        );
    }

    #[test]
    fn budget_respected() {
        let mut obj = Separable::new(3, 3);
        let mut tpe = Tpe::new(TpeParams::default());
        let hist = tpe.run(&mut obj, 25);
        assert_eq!(hist.len(), 25);
    }
}
