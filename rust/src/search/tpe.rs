//! Vanilla tree-structured Parzen estimator (Bergstra et al., 2011) — the
//! baseline the paper's k-means TPE is measured against (Fig. 3).
//!
//! Single quantile threshold: after n0 random startup trials, split observed
//! objective values at the γ-quantile; l(x) fits the top γ fraction, g(x)
//! the rest; propose argmax l/g among candidates sampled from l.
//!
//! Like [`KmeansTpeState`](super::kmeans_tpe::KmeansTpeState), the proposal
//! path is incremental: [`TpeState`] keeps the trial indices sorted by value
//! (one binary-search insert per observation instead of a full re-sort) and
//! diff-maintains the l/g Parzens as the γ-quantile boundary drifts. The
//! shared [`propose`] then runs on the Parzens' lazily-rebuilt per-dim
//! log-prob and threshold tables, so this baseline inherits the same
//! vectorized candidate loop as the k-means variant.

use super::history::History;
use super::parzen::{propose, SurrogatePair};
use super::space::{Config, Space};
use super::{Objective, Searcher};
use crate::util::rng::Rng;
use crate::util::Timer;

#[derive(Debug, Clone, Copy)]
pub struct TpeParams {
    /// Random startup trials before the surrogates are built.
    pub n_startup: usize,
    /// Top quantile treated as desirable (paper/HyperOpt default 0.25).
    pub gamma: f64,
    /// Candidates drawn from l(x) per proposal.
    pub n_candidates: usize,
    pub prior_weight: f64,
    pub seed: u64,
}

impl Default for TpeParams {
    fn default() -> Self {
        TpeParams { n_startup: 20, gamma: 0.25, n_candidates: 24, prior_weight: 1.0, seed: 0 }
    }
}

impl TpeParams {
    /// Reject parameterizations that would panic or degenerate downstream.
    pub fn validate(&self) -> Result<(), String> {
        if self.n_candidates == 0 {
            return Err("n_candidates must be >= 1".to_string());
        }
        if !(self.gamma.is_finite() && self.gamma > 0.0 && self.gamma <= 1.0) {
            return Err(format!("gamma must be in (0, 1], got {}", self.gamma));
        }
        if !(self.prior_weight.is_finite() && self.prior_weight > 0.0) {
            return Err(format!(
                "prior_weight must be positive and finite, got {}",
                self.prior_weight
            ));
        }
        Ok(())
    }
}

pub struct Tpe {
    pub params: TpeParams,
}

impl Tpe {
    pub fn new(params: TpeParams) -> Tpe {
        if let Err(e) = params.validate() {
            panic!("invalid TpeParams: {e}");
        }
        Tpe { params }
    }
}

/// Incrementally maintained vanilla-TPE surrogate state (see module docs).
pub struct TpeState {
    pub params: TpeParams,
    space: Space,
    configs: Vec<Config>,
    values: Vec<f64>,
    /// Trial indices sorted by DECREASING value (ties: insertion order),
    /// maintained by binary-search insertion — never re-sorted.
    order: Vec<usize>,
    surr: SurrogatePair,
}

impl TpeState {
    pub fn new(params: TpeParams, space: Space) -> TpeState {
        if let Err(e) = params.validate() {
            panic!("invalid TpeParams: {e}");
        }
        let surr = SurrogatePair::new(&space, params.prior_weight);
        TpeState {
            params,
            space,
            configs: Vec::new(),
            values: Vec::new(),
            order: Vec::new(),
            surr,
        }
    }

    /// Rebuild a state from checkpointed trials by replaying them: the value
    /// ordering is a pure, deterministic function of the observation
    /// sequence, so unlike `KmeansTpeState` there is no extra cursor to
    /// carry.
    pub fn restore(
        params: TpeParams,
        space: Space,
        configs: Vec<Config>,
        values: Vec<f64>,
    ) -> TpeState {
        assert_eq!(configs.len(), values.len(), "restore: configs/values disagree");
        for (i, c) in configs.iter().enumerate() {
            // Same contract as `KmeansTpeState::restore`: cross-space
            // histories must be projected before they reach a surrogate.
            assert!(
                space.validate(c),
                "restore: trial {i} ({c:?}) is invalid for this space — project the \
                 checkpoint onto it first"
            );
        }
        let mut state = TpeState::new(params, space);
        for (config, value) in configs.into_iter().zip(values) {
            state.observe(config, value);
        }
        state
    }

    pub fn space(&self) -> &Space {
        &self.space
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Record one completed trial: a binary-search insert into the value
    /// ordering (NaN values sort last).
    pub fn observe(&mut self, config: Config, value: f64) {
        let idx = self.values.len();
        self.configs.push(config);
        self.values.push(value);
        let values = &self.values;
        // First position whose value sorts strictly below `value`: equal
        // values keep insertion order, matching a stable descending sort.
        // NaN is ordered below every finite value (an incoming NaN goes to
        // the end; a stored NaN never outranks a finite insert), keeping the
        // sequence partitioned — `partial_cmp != Less` alone would leave
        // stored NaNs "true" at the tail and silently corrupt the binary
        // search.
        let pos = if value.is_nan() {
            self.order.len()
        } else {
            use std::cmp::Ordering::{Equal, Greater};
            self.order.partition_point(|&t| {
                matches!(values[t].partial_cmp(&value), Some(Greater) | Some(Equal))
            })
        };
        self.order.insert(pos, idx);
    }

    /// Re-point l at the top-γ fraction and g at the rest, via diffs.
    fn refresh_surrogates(&mut self) {
        let n = self.values.len();
        let n_top = (((n as f64) * self.params.gamma).ceil().max(1.0) as usize).min(n);
        let mut in_l = vec![false; n];
        let mut in_g = vec![false; n];
        for (rank, &t) in self.order.iter().enumerate() {
            if rank < n_top {
                in_l[t] = true;
            } else {
                in_g[t] = true;
            }
        }
        self.surr.retarget(&self.configs, &in_l, &in_g);
    }

    /// Propose one config; prior sample while no observations exist.
    pub fn propose(&mut self, rng: &mut Rng) -> Config {
        if self.values.is_empty() {
            return self.space.sample(rng);
        }
        self.refresh_surrogates();
        propose(&self.surr.l, &self.surr.g, rng, self.params.n_candidates)
    }

    /// Constant-liar batch proposal: pending proposals are imputed into g(x)
    /// while the rest of the round is drawn, then removed (see
    /// `KmeansTpeState::propose_batch` for the rationale).
    pub fn propose_batch(&mut self, q: usize, rng: &mut Rng) -> Vec<Config> {
        if self.values.is_empty() {
            return (0..q).map(|_| self.space.sample(rng)).collect();
        }
        self.refresh_surrogates();
        let mut out: Vec<Config> = Vec::with_capacity(q);
        for _ in 0..q {
            let cand = propose(&self.surr.l, &self.surr.g, rng, self.params.n_candidates);
            self.surr.g.add(&cand);
            out.push(cand);
        }
        for cand in &out {
            self.surr.g.remove(cand);
        }
        out
    }
}

impl Searcher for Tpe {
    fn name(&self) -> &'static str {
        "tpe"
    }

    fn run(&mut self, obj: &mut dyn Objective, budget: usize) -> History {
        let mut rng = Rng::new(self.params.seed ^ 0x79E);
        let mut hist = History::new(self.name());
        let mut state = TpeState::new(self.params, obj.space().clone());

        for i in 0..budget {
            let config: Config = if i < self.params.n_startup {
                state.space().sample(&mut rng)
            } else {
                state.propose(&mut rng)
            };
            let t = Timer::start();
            let value = obj.eval(&config);
            hist.push(config.clone(), value, t.secs());
            state.observe(config, value);
        }
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::space::{Dim, Space};

    /// Separable synthetic objective: value = sum of per-dim scores, best at
    /// choice 0 everywhere.
    pub struct Separable {
        space: Space,
    }

    impl Separable {
        pub fn new(dims: usize, k: usize) -> Separable {
            let space = Space::new(
                (0..dims)
                    .map(|d| {
                        Dim::new(format!("d{d}"), (0..k).map(|c| c as f64).collect())
                    })
                    .collect(),
            );
            Separable { space }
        }
    }

    impl Objective for Separable {
        fn space(&self) -> &Space {
            &self.space
        }

        fn eval(&mut self, config: &Config) -> f64 {
            -(config.iter().map(|&c| c as f64).sum::<f64>())
        }
    }

    #[test]
    fn beats_random_on_separable() {
        // Statistical comparison over seeds (single runs are noisy).
        let budget = 60;
        let seeds = 0..8u64;
        let mut tpe_sum = 0.0;
        let mut rand_sum = 0.0;
        for seed in seeds {
            let mut obj = Separable::new(8, 4);
            let mut tpe =
                Tpe::new(TpeParams { n_startup: 15, seed, ..Default::default() });
            tpe_sum += tpe.run(&mut obj, budget).best().unwrap().value;

            let mut rng = Rng::new(seed ^ 0x5EED);
            let mut obj2 = Separable::new(8, 4);
            let space = obj2.space().clone();
            rand_sum += (0..budget)
                .map(|_| {
                    let c = space.sample(&mut rng);
                    obj2.eval(&c)
                })
                .fold(f64::NEG_INFINITY, f64::max);
        }
        assert!(
            tpe_sum >= rand_sum,
            "tpe mean {} vs random mean {}",
            tpe_sum / 8.0,
            rand_sum / 8.0
        );
    }

    #[test]
    fn budget_respected() {
        let mut obj = Separable::new(3, 3);
        let mut tpe = Tpe::new(TpeParams::default());
        let hist = tpe.run(&mut obj, 25);
        assert_eq!(hist.len(), 25);
    }

    #[test]
    fn incremental_order_matches_stable_sort() {
        let space = Separable::new(2, 3).space.clone();
        let mut state = TpeState::new(TpeParams::default(), space.clone());
        let vals = [0.3, 0.9, 0.3, -1.0, 0.9, 0.0, 2.5];
        let mut rng = Rng::new(11);
        for &v in &vals {
            state.observe(space.sample(&mut rng), v);
        }
        // Reference: the seed implementation's stable descending sort.
        let mut expect: Vec<usize> = (0..vals.len()).collect();
        expect.sort_by(|&a, &b| vals[b].partial_cmp(&vals[a]).unwrap());
        assert_eq!(state.order, expect);
    }

    #[test]
    fn observe_tolerates_nan_values() {
        let space = Separable::new(2, 3).space.clone();
        let mut state = TpeState::new(TpeParams::default(), space.clone());
        let mut rng = Rng::new(12);
        for &v in &[0.5, f64::NAN, 0.9, f64::NAN, -0.2, 1.4] {
            state.observe(space.sample(&mut rng), v);
        }
        // Finite values stay stably descending; NaNs sink to the end.
        let ranked: Vec<f64> = state.order.iter().map(|&t| state.values[t]).collect();
        assert_eq!(&ranked[..4], &[1.4, 0.9, 0.5, -0.2]);
        assert!(ranked[4..].iter().all(|v| v.is_nan()));
    }

    #[test]
    fn surrogates_match_from_scratch_quantile_split() {
        use crate::search::parzen::Parzen;
        let space = Separable::new(3, 4).space.clone();
        let params = TpeParams::default();
        let mut state = TpeState::new(params, space.clone());
        let mut rng = Rng::new(5);
        for i in 0..37 {
            let c = space.sample(&mut rng);
            state.observe(c, (i % 9) as f64 * 0.1);
        }
        state.refresh_surrogates();

        // From-scratch split, exactly as the seed implementation did it.
        let n = state.len();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| state.values[b].partial_cmp(&state.values[a]).unwrap());
        let n_top = ((n as f64) * params.gamma).ceil().max(1.0) as usize;
        let top: Vec<&Config> = order[..n_top].iter().map(|&i| &state.configs[i]).collect();
        let rest: Vec<&Config> = order[n_top..].iter().map(|&i| &state.configs[i]).collect();
        let l = Parzen::fit(&space, &top, params.prior_weight);
        let g = Parzen::fit(&space, &rest, params.prior_weight);
        assert!(state.surr.l.same_counts(&l));
        assert!(state.surr.g.same_counts(&g));
    }
}
