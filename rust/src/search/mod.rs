//! Sequential + batched model-based search: the paper's k-means TPE
//! (§III-B, Alg. 1), the vanilla TPE it is compared against, the shared
//! machinery (search space, Parzen surrogates, trial history), and the
//! batched-proposal / parallel-evaluation engine (`batch`).

pub mod space;
pub mod parzen;
pub mod history;
pub mod tpe;
pub mod kmeans_tpe;
pub mod batch;
pub mod checkpoint;
pub mod synthetic;

pub use batch::{eval_batch_parallel, BatchAlgo, BatchRun, BatchSearcher, CachedObjective,
                ParallelObjective, QPolicy, RoundStat};
pub use checkpoint::{RngState, SearchCheckpoint};
pub use synthetic::SyntheticObjective;
pub use history::{History, Trial};
pub use kmeans_tpe::{KmeansTpe, KmeansTpeParams, KmeansTpeState};
pub use space::{Config, Dim, Space};
pub use tpe::{Tpe, TpeParams, TpeState};

/// A maximization objective over a categorical search space.
///
/// Implementations: the DNN config evaluator (proxy QAT + hardware model),
/// the mlbase hyperparameter objectives (Fig. 3a/3b), synthetic test
/// functions, and the remote worker-pool objective.
pub trait Objective {
    fn space(&self) -> &Space;
    /// Evaluate one configuration (indices into each dim's choices).
    fn eval(&mut self, config: &Config) -> f64;

    /// Evaluate a whole proposal batch, returning values in input order.
    ///
    /// The default is a sequential loop, so every existing objective is
    /// batch-capable unchanged. Override to exploit real parallelism:
    /// [`batch::ParallelObjective`] fans a batch across thread-local
    /// replicas, and the coordinator's `RemoteObjective` work-steals it
    /// across its async worker pool.
    fn eval_batch(&mut self, configs: &[Config]) -> Vec<f64> {
        configs.iter().map(|c| self.eval(c)).collect()
    }

    /// How many evaluations this objective can usefully run concurrently —
    /// the upper bound an adaptive batch-size controller should saturate.
    /// The default (1) is right for in-process sequential objectives;
    /// `ParallelObjective` reports its replica count and the coordinator's
    /// `RemoteObjective` its live worker count.
    fn parallelism(&self) -> usize {
        1
    }
}

/// A search algorithm consuming `budget` objective evaluations.
pub trait Searcher {
    fn name(&self) -> &'static str;
    fn run(&mut self, obj: &mut dyn Objective, budget: usize) -> History;
}
