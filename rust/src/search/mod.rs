//! Sequential + batched model-based search: the paper's k-means TPE
//! (§III-B, Alg. 1), the vanilla TPE it is compared against, the shared
//! machinery (search space, Parzen surrogates, trial history), and the
//! batched-proposal / parallel-evaluation engine (`batch`).

pub mod space;
pub mod parzen;
pub mod history;
pub mod tpe;
pub mod kmeans_tpe;
pub mod batch;
pub mod checkpoint;
pub mod costmodel;
pub mod project;
pub mod synthetic;
pub mod warehouse;

pub use batch::{eval_batch_parallel, BatchAlgo, BatchRun, BatchSearcher, CachedObjective,
                ParallelObjective, QPolicy, RoundStat, EVAL_CACHE_CAP};
pub use checkpoint::{RngState, SearchCheckpoint};
pub use project::{ProjectPolicy, ProjectionOutcome, ProjectionReport, SpaceProjection};
pub use warehouse::{cfg_digest, warehouse_key, GcOutcome, KeySummary, StoredHistory,
                    WarmStart, Warehouse, WAREHOUSE_MANIFEST};
pub use costmodel::CostModel;
pub use synthetic::SyntheticObjective;
pub use history::{History, Trial};
pub use kmeans_tpe::{KmeansTpe, KmeansTpeParams, KmeansTpeState};
pub use space::{Config, Dim, Space};
pub use tpe::{Tpe, TpeParams, TpeState};

/// A maximization objective over a categorical search space.
///
/// Implementations: the DNN config evaluator (proxy QAT + hardware model),
/// the mlbase hyperparameter objectives (Fig. 3a/3b), synthetic test
/// functions, and the remote worker-pool objective.
pub trait Objective {
    fn space(&self) -> &Space;
    /// Evaluate one configuration (indices into each dim's choices).
    fn eval(&mut self, config: &Config) -> f64;

    /// Evaluate a whole proposal batch, returning values in input order.
    ///
    /// The default is a sequential loop, so every existing objective is
    /// batch-capable unchanged. Override to exploit real parallelism:
    /// [`batch::ParallelObjective`] fans a batch across thread-local
    /// replicas, and the coordinator's `RemoteObjective` work-steals it
    /// across its async worker pool.
    fn eval_batch(&mut self, configs: &[Config]) -> Vec<f64> {
        configs.iter().map(|c| self.eval(c)).collect()
    }

    /// [`eval_batch`](Self::eval_batch), additionally reporting each
    /// config's own evaluation wall-clock — the observations the
    /// scheduler's per-config cost model ([`costmodel::CostModel`]) fits.
    /// The default times each sequential `eval` individually, which is
    /// exact for any objective that keeps the default `eval_batch`.
    ///
    /// IMPORTANT: an objective that overrides `eval_batch` must override
    /// this too (returning the same values), or callers on the timed path
    /// silently lose the override's parallelism/caching — see
    /// `ParallelObjective`, `CachedObjective`, and the coordinator's
    /// `RemoteObjective` for the three shipped pairings.
    fn eval_batch_timed(&mut self, configs: &[Config]) -> (Vec<f64>, Vec<f64>) {
        let mut values = Vec::with_capacity(configs.len());
        let mut secs = Vec::with_capacity(configs.len());
        for c in configs {
            let t = std::time::Instant::now();
            values.push(self.eval(c));
            secs.push(t.elapsed().as_secs_f64());
        }
        (values, secs)
    }

    /// How many evaluations this objective can usefully run concurrently —
    /// the upper bound an adaptive batch-size controller should saturate.
    /// The default (1) is right for in-process sequential objectives;
    /// `ParallelObjective` reports its replica count and the coordinator's
    /// `RemoteObjective` its live worker count.
    fn parallelism(&self) -> usize {
        1
    }
}

/// A search algorithm consuming `budget` objective evaluations.
pub trait Searcher {
    fn name(&self) -> &'static str;
    fn run(&mut self, obj: &mut dyn Objective, budget: usize) -> History;
}
