//! Sequential model-based search: the paper's k-means TPE (§III-B, Alg. 1),
//! the vanilla TPE it is compared against, and the shared machinery
//! (search space, Parzen surrogates, trial history).

pub mod space;
pub mod parzen;
pub mod history;
pub mod tpe;
pub mod kmeans_tpe;

pub use history::{History, Trial};
pub use kmeans_tpe::{KmeansTpe, KmeansTpeParams};
pub use space::{Config, Dim, Space};
pub use tpe::{Tpe, TpeParams};

/// A maximization objective over a categorical search space.
///
/// Implementations: the DNN config evaluator (proxy QAT + hardware model),
/// the mlbase hyperparameter objectives (Fig. 3a/3b), and synthetic test
/// functions.
pub trait Objective {
    fn space(&self) -> &Space;
    /// Evaluate one configuration (indices into each dim's choices).
    fn eval(&mut self, config: &Config) -> f64;
}

/// A search algorithm consuming `budget` objective evaluations.
pub trait Searcher {
    fn name(&self) -> &'static str;
    fn run(&mut self, obj: &mut dyn Objective, budget: usize) -> History;
}
