//! k-means TPE — the paper's core optimizer (§III-B, Alg. 1).
//!
//! Vanilla TPE's single quantile threshold misbehaves on the flat loss
//! landscapes of DNNs: configurations from promising regions whose objective
//! lands *slightly* below the threshold are pushed into g(x), steering the
//! search away from them. The dual-threshold variant instead k-means-clusters
//! the observed objective values, fits l(x) ONLY to the top cluster C1 and
//! g(x) ONLY to the bottom cluster Ck, and leaves the ambiguous middle
//! clusters out of both surrogates.
//!
//! Annealing (Alg. 1): k = ceil(1/c) with c starting at 0.25 and decaying by
//! α per iteration, so k grows over time — cluster membership criteria
//! tighten, move sizes shrink, and the search anneals from global exploration
//! to local refinement.

use super::history::History;
use super::parzen::{propose, Parzen};
use super::space::Config;
use super::{Objective, Searcher};
use crate::kmeans::kmeans_1d;
use crate::util::rng::Rng;
use crate::util::Timer;

#[derive(Debug, Clone, Copy)]
pub struct KmeansTpeParams {
    /// Random startup trials (paper: n0 = 20 for tabular, 40 for DNNs).
    pub n_startup: usize,
    /// Initial cluster-count control: k = ceil(1/c). Paper: c = 0.25 => k=4.
    pub c0: f64,
    /// Annealing factor per iteration. Paper: α = 0.98.
    pub alpha: f64,
    /// Candidates drawn from l(x) per proposal.
    pub n_candidates: usize,
    pub prior_weight: f64,
    pub seed: u64,
    /// Ablation: disable annealing (k stays at ceil(1/c0)).
    pub anneal: bool,
    /// Ablation: single-threshold mode (g(x) fits ALL non-C1 clusters, i.e.
    /// what a quantile split would do with the same C1).
    pub dual_threshold: bool,
}

impl Default for KmeansTpeParams {
    fn default() -> Self {
        KmeansTpeParams {
            n_startup: 20,
            c0: 0.25,
            alpha: 0.98,
            n_candidates: 24,
            prior_weight: 1.0,
            seed: 0,
            anneal: true,
            dual_threshold: true,
        }
    }
}

pub struct KmeansTpe {
    pub params: KmeansTpeParams,
}

impl KmeansTpe {
    pub fn new(params: KmeansTpeParams) -> KmeansTpe {
        KmeansTpe { params }
    }

    /// Current cluster count for annealing step `iter` (0-based):
    /// k = ceil(1 / (c0 * alpha^iter)), clamped to at least 3 (the paper
    /// requires k >= 3 so a non-trivial middle exists) and at most the
    /// number of observations.
    pub fn k_at(&self, iter: usize, n_obs: usize) -> usize {
        let c = if self.params.anneal {
            self.params.c0 * self.params.alpha.powi(iter as i32)
        } else {
            self.params.c0
        };
        let k = (1.0 / c).ceil() as usize;
        k.max(3).min(n_obs.max(3))
    }
}

impl Searcher for KmeansTpe {
    fn name(&self) -> &'static str {
        "kmeans-tpe"
    }

    fn run(&mut self, obj: &mut dyn Objective, budget: usize) -> History {
        let mut rng = Rng::new(self.params.seed ^ 0x6B7E);
        let mut hist = History::new(self.name());
        let space = obj.space().clone();

        for i in 0..budget {
            let config: Config = if i < self.params.n_startup.min(budget) {
                space.sample(&mut rng)
            } else {
                let values = hist.values();
                let k = self.k_at(i - self.params.n_startup, values.len());
                let clustering = kmeans_1d(&values, k);
                // C1 = top-centroid cluster, Ck = bottom-centroid cluster
                // (centroids are sorted decreasing).
                let top_cluster = 0;
                let bottom_cluster = clustering.k() - 1;
                let desirable: Vec<&Config> = clustering.members[top_cluster]
                    .iter()
                    .map(|&t| &hist.trials[t].config)
                    .collect();
                let undesirable: Vec<&Config> = if self.params.dual_threshold {
                    clustering.members[bottom_cluster]
                        .iter()
                        .map(|&t| &hist.trials[t].config)
                        .collect()
                } else {
                    // Ablation: everything outside C1 feeds g(x).
                    (0..clustering.k())
                        .skip(1)
                        .flat_map(|cl| clustering.members[cl].iter())
                        .map(|&t| &hist.trials[t].config)
                        .collect()
                };
                let l = Parzen::fit(&space, &desirable, self.params.prior_weight);
                let g = Parzen::fit(&space, &undesirable, self.params.prior_weight);
                propose(&l, &g, &mut rng, self.params.n_candidates)
            };
            let t = Timer::start();
            let value = obj.eval(&config);
            hist.push(config, value, t.secs());
        }
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::space::{Dim, Space};
    use crate::search::tpe::{Tpe, TpeParams};

    /// Flat-landscape objective modeling the paper's motivation: the value is
    /// a STEP function of the config quality (many configs share near-equal
    /// objective values), plus a tiny tie-breaking slope. Single-threshold
    /// TPE mixes the wide "good plateau" into g(x); dual-threshold k-means
    /// TPE keeps the plateau out of g(x) and converges faster.
    struct FlatPlateau {
        space: Space,
    }

    impl FlatPlateau {
        fn new(dims: usize) -> FlatPlateau {
            let space = Space::new(
                (0..dims)
                    .map(|d| Dim::new(format!("d{d}"), vec![0.0, 1.0, 2.0, 3.0]))
                    .collect(),
            );
            FlatPlateau { space }
        }
    }

    impl super::super::Objective for FlatPlateau {
        fn space(&self) -> &Space {
            &self.space
        }

        fn eval(&mut self, config: &Config) -> f64 {
            let good = config.iter().filter(|&&c| c == 0).count() as f64;
            let n = config.len() as f64;
            // Plateaus at 0.5 / 0.8 / 1.0 with hairline slopes.
            let frac = good / n;
            if frac >= 0.95 {
                1.0
            } else if frac >= 0.5 {
                0.8 + 0.001 * frac
            } else {
                0.5 + 0.001 * frac
            }
        }
    }

    #[test]
    fn k_annealing_schedule() {
        let kt = KmeansTpe::new(KmeansTpeParams { c0: 0.25, alpha: 0.9, ..Default::default() });
        assert_eq!(kt.k_at(0, 1000), 4);
        assert!(kt.k_at(20, 1000) > 4);
        // No annealing ablation: constant k.
        let kt2 = KmeansTpe::new(KmeansTpeParams {
            c0: 0.25,
            anneal: false,
            ..Default::default()
        });
        assert_eq!(kt2.k_at(50, 1000), 4);
        // Clamped by observation count.
        assert!(kt.k_at(200, 5) <= 5);
    }

    #[test]
    fn budget_respected_and_deterministic() {
        let mut obj = FlatPlateau::new(6);
        let p = KmeansTpeParams { n_startup: 10, seed: 7, ..Default::default() };
        let h1 = KmeansTpe::new(p).run(&mut obj, 30);
        let h2 = KmeansTpe::new(p).run(&mut FlatPlateau::new(6), 30);
        assert_eq!(h1.len(), 30);
        assert_eq!(h1.values(), h2.values());
    }

    #[test]
    fn converges_faster_than_tpe_on_flat_landscape() {
        // Compare median evaluations-to-best over several seeds, mirroring
        // the Fig. 3 protocol (n0=20, n=100, k=4, alpha=0.98).
        let budget = 100;
        let mut km_evals = Vec::new();
        let mut tpe_evals = Vec::new();
        for seed in 0..7 {
            let mut obj = FlatPlateau::new(8);
            let h = KmeansTpe::new(KmeansTpeParams {
                n_startup: 20,
                seed,
                ..Default::default()
            })
            .run(&mut obj, budget);
            km_evals.push(h.evals_to_reach(1.0).unwrap_or(budget + 1) as f64);

            let mut obj = FlatPlateau::new(8);
            let h = Tpe::new(TpeParams { n_startup: 20, seed, ..Default::default() })
                .run(&mut obj, budget);
            tpe_evals.push(h.evals_to_reach(1.0).unwrap_or(budget + 1) as f64);
        }
        let med = |v: &[f64]| crate::util::stats::quantile(v, 0.5);
        assert!(
            med(&km_evals) <= med(&tpe_evals),
            "kmeans-tpe {km_evals:?} vs tpe {tpe_evals:?}"
        );
    }
}
