//! k-means TPE — the paper's core optimizer (§III-B, Alg. 1).
//!
//! Vanilla TPE's single quantile threshold misbehaves on the flat loss
//! landscapes of DNNs: configurations from promising regions whose objective
//! lands *slightly* below the threshold are pushed into g(x), steering the
//! search away from them. The dual-threshold variant instead k-means-clusters
//! the observed objective values, fits l(x) ONLY to the top cluster C1 and
//! g(x) ONLY to the bottom cluster Ck, and leaves the ambiguous middle
//! clusters out of both surrogates.
//!
//! Annealing (Alg. 1): k = ceil(1/c) with c starting at 0.25 and decaying by
//! α per iteration, so k grows over time — cluster membership criteria
//! tighten, move sizes shrink, and the search anneals from global exploration
//! to local refinement.
//!
//! The proposal hot path is INCREMENTAL (see [`KmeansTpeState`]): k-means
//! warm-starts from the previous iteration's centroids and the l/g Parzens
//! are diff-maintained, so one proposal costs roughly O(n·k) for a 1–2 pass
//! Lloyd refresh plus O(changed · dims) surrogate updates — instead of the
//! from-scratch O(n log n + n·k·iters + n·dims) refit the seed implementation
//! paid. Inside [`propose`] the Parzens are consumed through flat per-dim
//! tables ([`Parzen`](super::parzen::Parzen) caches log-probabilities for
//! scoring and cumulative-count thresholds for sampling, rebuilt lazily only
//! for dims whose counts changed), so the candidate loop is table lookups +
//! one partial-select rather than per-candidate log/divide chains. The
//! `tpe-hotpath` bench gates the combined gap at >= 20x for history 1000.

use super::history::History;
use super::parzen::{propose, SurrogatePair};
use super::space::{Config, Space};
use super::{Objective, Searcher};
use crate::kmeans::kmeans_1d_warm;
use crate::util::rng::Rng;
use crate::util::Timer;

#[derive(Debug, Clone, Copy)]
pub struct KmeansTpeParams {
    /// Random startup trials (paper: n0 = 20 for tabular, 40 for DNNs).
    pub n_startup: usize,
    /// Initial cluster-count control: k = ceil(1/c). Paper: c = 0.25 => k=4.
    pub c0: f64,
    /// Annealing factor per iteration. Paper: α = 0.98.
    pub alpha: f64,
    /// Candidates drawn from l(x) per proposal.
    pub n_candidates: usize,
    pub prior_weight: f64,
    pub seed: u64,
    /// Ablation: disable annealing (k stays at ceil(1/c0)).
    pub anneal: bool,
    /// Ablation: single-threshold mode (g(x) fits ALL non-C1 clusters, i.e.
    /// what a quantile split would do with the same C1).
    pub dual_threshold: bool,
}

impl Default for KmeansTpeParams {
    fn default() -> Self {
        KmeansTpeParams {
            n_startup: 20,
            c0: 0.25,
            alpha: 0.98,
            n_candidates: 24,
            prior_weight: 1.0,
            seed: 0,
            anneal: true,
            dual_threshold: true,
        }
    }
}

impl KmeansTpeParams {
    /// Reject parameterizations that would panic or loop forever downstream.
    /// Fuzz-guarded by a property test: any params accepted here must run.
    pub fn validate(&self) -> Result<(), String> {
        if self.n_candidates == 0 {
            return Err("n_candidates must be >= 1".to_string());
        }
        if !(self.c0.is_finite() && self.c0 > 0.0) {
            return Err(format!("c0 must be positive and finite, got {}", self.c0));
        }
        if !(self.alpha.is_finite() && self.alpha > 0.0 && self.alpha <= 1.0) {
            return Err(format!("alpha must be in (0, 1], got {}", self.alpha));
        }
        if !(self.prior_weight.is_finite() && self.prior_weight > 0.0) {
            return Err(format!(
                "prior_weight must be positive and finite, got {}",
                self.prior_weight
            ));
        }
        Ok(())
    }
}

pub struct KmeansTpe {
    pub params: KmeansTpeParams,
}

impl KmeansTpe {
    /// Panics on invalid params — use [`KmeansTpeParams::validate`] first
    /// when the values come from user input.
    pub fn new(params: KmeansTpeParams) -> KmeansTpe {
        if let Err(e) = params.validate() {
            panic!("invalid KmeansTpeParams: {e}");
        }
        KmeansTpe { params }
    }

    /// Current cluster count for annealing step `iter` (0-based):
    /// k = ceil(1 / (c0 * alpha^iter)), clamped to at least 3 (the paper
    /// requires k >= 3 so a non-trivial middle exists) and at most the
    /// number of observations.
    pub fn k_at(&self, iter: usize, n_obs: usize) -> usize {
        k_schedule(&self.params, iter, n_obs)
    }
}

fn k_schedule(params: &KmeansTpeParams, iter: usize, n_obs: usize) -> usize {
    let c = if params.anneal {
        params.c0 * params.alpha.powi(iter as i32)
    } else {
        params.c0
    };
    let k = (1.0 / c).ceil() as usize;
    k.max(3).min(n_obs.max(3))
}

/// Incrementally maintained k-means-TPE surrogate state.
///
/// Owns the observed (config, value) history plus everything needed to make
/// the next proposal cheap: the previous clustering's centroids (warm start
/// for Lloyd) and a diff-maintained [`SurrogatePair`]. Drives both the
/// sequential [`KmeansTpe`] searcher (q = 1) and the batched constant-liar
/// path (`propose_batch`, used by `search::batch::BatchSearcher`).
pub struct KmeansTpeState {
    pub params: KmeansTpeParams,
    space: Space,
    configs: Vec<Config>,
    values: Vec<f64>,
    surr: SurrogatePair,
    /// Proposal rounds made so far — drives the annealing schedule.
    iter: usize,
    /// Previous clustering's centroids (decreasing), for warm-started Lloyd.
    warm: Vec<f64>,
}

impl KmeansTpeState {
    pub fn new(params: KmeansTpeParams, space: Space) -> KmeansTpeState {
        if let Err(e) = params.validate() {
            panic!("invalid KmeansTpeParams: {e}");
        }
        let surr = SurrogatePair::new(&space, params.prior_weight);
        KmeansTpeState {
            params,
            space,
            configs: Vec::new(),
            values: Vec::new(),
            surr,
            iter: 0,
            warm: Vec::new(),
        }
    }

    /// Rebuild a state frozen at a round boundary (search checkpointing).
    /// `iter` and `warm` come from [`rounds`](Self::rounds) /
    /// [`warm_centroids`](Self::warm_centroids) of the interrupted state:
    /// replaying observations alone would reset the annealing schedule to
    /// k(0) and drop the Lloyd warm start, silently changing every
    /// subsequent clustering. The surrogates start from the prior and
    /// re-point on the next proposal — exactly the fit of the restored
    /// membership, since Parzen counts are order-independent (+1.0 adds are
    /// exact in f64).
    pub fn restore(
        params: KmeansTpeParams,
        space: Space,
        configs: Vec<Config>,
        values: Vec<f64>,
        iter: usize,
        warm: Vec<f64>,
    ) -> KmeansTpeState {
        assert_eq!(configs.len(), values.len(), "restore: configs/values disagree");
        for (i, c) in configs.iter().enumerate() {
            // A config outside the space means the caller skipped the
            // fingerprint guard / projection step — refitting surrogates
            // from it would silently corrupt every later proposal.
            assert!(
                space.validate(c),
                "restore: trial {i} ({c:?}) is invalid for this space — project the \
                 checkpoint onto it first"
            );
        }
        let mut state = KmeansTpeState::new(params, space);
        state.configs = configs;
        state.values = values;
        state.iter = iter;
        state.warm = warm;
        state
    }

    pub fn space(&self) -> &Space {
        &self.space
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Proposal rounds taken so far (drives the annealing schedule).
    pub fn rounds(&self) -> usize {
        self.iter
    }

    /// Previous clustering's centroids (the Lloyd warm start).
    pub fn warm_centroids(&self) -> &[f64] {
        &self.warm
    }

    /// Record one completed trial: O(1) — surrogates refresh lazily on the
    /// next proposal, via cluster-membership diffs.
    pub fn observe(&mut self, config: Config, value: f64) {
        self.configs.push(config);
        self.values.push(value);
    }

    /// Recluster (warm-started) and re-point l/g at C1 / Ck via diffs.
    fn refresh_surrogates(&mut self) {
        let k = k_schedule(&self.params, self.iter, self.values.len());
        let warm = if self.warm.is_empty() { None } else { Some(self.warm.as_slice()) };
        let clustering = kmeans_1d_warm(&self.values, k, warm);
        self.warm = clustering.centroids.clone();

        let n = self.values.len();
        let mut in_l = vec![false; n];
        let mut in_g = vec![false; n];
        let bottom = clustering.k() - 1;
        for (i, &a) in clustering.assignment.iter().enumerate() {
            // C1 = top-centroid cluster, Ck = bottom-centroid cluster
            // (centroids are sorted decreasing).
            if a == 0 {
                in_l[i] = true;
            } else if self.params.dual_threshold {
                in_g[i] = a == bottom;
            } else {
                // Ablation: everything outside C1 feeds g(x).
                in_g[i] = true;
            }
        }
        self.surr.retarget(&self.configs, &in_l, &in_g);
    }

    /// Propose one config (sequential path). Falls back to a prior sample
    /// while no observations exist.
    pub fn propose(&mut self, rng: &mut Rng) -> Config {
        if self.values.is_empty() {
            return self.space.sample(rng);
        }
        self.refresh_surrogates();
        self.iter += 1;
        propose(&self.surr.l, &self.surr.g, rng, self.params.n_candidates)
    }

    /// Propose `q` configs for one evaluation round using the constant-liar
    /// strategy: each pending proposal is pessimistically imputed into g(x)
    /// (as if it had landed in the undesirable cluster) before the next one
    /// is drawn, so the batch spreads over modes instead of collapsing onto
    /// the single argmax of l/g. The liar entries are removed afterwards —
    /// real values arrive through [`observe`](Self::observe).
    pub fn propose_batch(&mut self, q: usize, rng: &mut Rng) -> Vec<Config> {
        if self.values.is_empty() {
            return (0..q).map(|_| self.space.sample(rng)).collect();
        }
        self.refresh_surrogates();
        self.iter += 1; // one annealing step per round
        let mut out: Vec<Config> = Vec::with_capacity(q);
        for _ in 0..q {
            let cand = propose(&self.surr.l, &self.surr.g, rng, self.params.n_candidates);
            self.surr.g.add(&cand);
            out.push(cand);
        }
        for cand in &out {
            self.surr.g.remove(cand);
        }
        out
    }
}

impl Searcher for KmeansTpe {
    fn name(&self) -> &'static str {
        "kmeans-tpe"
    }

    fn run(&mut self, obj: &mut dyn Objective, budget: usize) -> History {
        let mut rng = Rng::new(self.params.seed ^ 0x6B7E);
        let mut hist = History::new(self.name());
        let mut state = KmeansTpeState::new(self.params, obj.space().clone());

        for i in 0..budget {
            let config: Config = if i < self.params.n_startup.min(budget) {
                state.space().sample(&mut rng)
            } else {
                state.propose(&mut rng)
            };
            let t = Timer::start();
            let value = obj.eval(&config);
            hist.push(config.clone(), value, t.secs());
            state.observe(config, value);
        }
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::space::{Dim, Space};
    use crate::search::tpe::{Tpe, TpeParams};
    use crate::util::proptest::check_no_shrink;

    /// Flat-landscape objective modeling the paper's motivation: the value is
    /// a STEP function of the config quality (many configs share near-equal
    /// objective values), plus a tiny tie-breaking slope. Single-threshold
    /// TPE mixes the wide "good plateau" into g(x); dual-threshold k-means
    /// TPE keeps the plateau out of g(x) and converges faster.
    struct FlatPlateau {
        space: Space,
    }

    impl FlatPlateau {
        fn new(dims: usize) -> FlatPlateau {
            let space = Space::new(
                (0..dims)
                    .map(|d| Dim::new(format!("d{d}"), vec![0.0, 1.0, 2.0, 3.0]))
                    .collect(),
            );
            FlatPlateau { space }
        }
    }

    impl super::super::Objective for FlatPlateau {
        fn space(&self) -> &Space {
            &self.space
        }

        fn eval(&mut self, config: &Config) -> f64 {
            let good = config.iter().filter(|&&c| c == 0).count() as f64;
            let n = config.len() as f64;
            // Plateaus at 0.5 / 0.8 / 1.0 with hairline slopes.
            let frac = good / n;
            if frac >= 0.95 {
                1.0
            } else if frac >= 0.5 {
                0.8 + 0.001 * frac
            } else {
                0.5 + 0.001 * frac
            }
        }
    }

    #[test]
    fn k_annealing_schedule() {
        let kt = KmeansTpe::new(KmeansTpeParams { c0: 0.25, alpha: 0.9, ..Default::default() });
        assert_eq!(kt.k_at(0, 1000), 4);
        assert!(kt.k_at(20, 1000) > 4);
        // No annealing ablation: constant k.
        let kt2 = KmeansTpe::new(KmeansTpeParams {
            c0: 0.25,
            anneal: false,
            ..Default::default()
        });
        assert_eq!(kt2.k_at(50, 1000), 4);
        // Clamped by observation count.
        assert!(kt.k_at(200, 5) <= 5);
    }

    #[test]
    fn budget_respected_and_deterministic() {
        let mut obj = FlatPlateau::new(6);
        let p = KmeansTpeParams { n_startup: 10, seed: 7, ..Default::default() };
        let h1 = KmeansTpe::new(p).run(&mut obj, 30);
        let h2 = KmeansTpe::new(p).run(&mut FlatPlateau::new(6), 30);
        assert_eq!(h1.len(), 30);
        assert_eq!(h1.values(), h2.values());
    }

    #[test]
    fn converges_faster_than_tpe_on_flat_landscape() {
        // Compare median evaluations-to-best over several seeds, mirroring
        // the Fig. 3 protocol (n0=20, n=100, k=4, alpha=0.98).
        let budget = 100;
        let mut km_evals = Vec::new();
        let mut tpe_evals = Vec::new();
        for seed in 0..7 {
            let mut obj = FlatPlateau::new(8);
            let h = KmeansTpe::new(KmeansTpeParams {
                n_startup: 20,
                seed,
                ..Default::default()
            })
            .run(&mut obj, budget);
            km_evals.push(h.evals_to_reach(1.0).unwrap_or(budget + 1) as f64);

            let mut obj = FlatPlateau::new(8);
            let h = Tpe::new(TpeParams { n_startup: 20, seed, ..Default::default() })
                .run(&mut obj, budget);
            tpe_evals.push(h.evals_to_reach(1.0).unwrap_or(budget + 1) as f64);
        }
        let med = |v: &[f64]| crate::util::stats::quantile(v, 0.5);
        assert!(
            med(&km_evals) <= med(&tpe_evals),
            "kmeans-tpe {km_evals:?} vs tpe {tpe_evals:?}"
        );
    }

    #[test]
    fn state_propose_on_empty_history_is_prior_sample() {
        let space = FlatPlateau::new(4).space.clone();
        let mut state = KmeansTpeState::new(KmeansTpeParams::default(), space.clone());
        let mut rng = Rng::new(0);
        let c = state.propose(&mut rng);
        assert!(space.validate(&c));
        let batch = state.propose_batch(3, &mut rng);
        assert_eq!(batch.len(), 3);
        assert!(batch.iter().all(|c| space.validate(c)));
    }

    #[test]
    fn propose_batch_cleans_up_liar_entries() {
        // Constant-liar imputations must be fully removed after the round:
        // with annealing off (constant k) a second surrogate refresh has no
        // membership flips, so the g counts after a batch round must equal
        // the pre-round counts exactly.
        let space = Space::new(vec![
            Dim::new("a", vec![0.0, 1.0, 2.0]),
            Dim::new("b", vec![0.0, 1.0, 2.0]),
        ]);
        let params = KmeansTpeParams { n_startup: 0, anneal: false, ..Default::default() };
        let mut state = KmeansTpeState::new(params, space.clone());
        let mut rng = Rng::new(13);
        for i in 0..12 {
            let c = space.sample(&mut rng);
            state.observe(c, (i % 5) as f64);
        }
        state.refresh_surrogates();
        let l_before = state.surr.l.clone();
        let g_before = state.surr.g.clone();
        let batch = state.propose_batch(5, &mut rng);
        assert_eq!(batch.len(), 5);
        assert!(state.surr.l.same_counts(&l_before), "l drifted across a batch round");
        assert!(state.surr.g.same_counts(&g_before), "g retained liar entries");
    }

    #[test]
    fn prop_params_fuzz_valid_or_rejected() {
        // Fuzz-guard: random (often garbage) params either fail validate()
        // with a clear error, or drive a small search without panicking.
        check_no_shrink(
            "kmeans-tpe-params-fuzz",
            96,
            |r: &mut Rng| KmeansTpeParams {
                n_startup: r.below(8),
                c0: (r.f64() - 0.2) * 3.0,
                alpha: r.f64() * 1.4,
                n_candidates: r.below(6),
                prior_weight: (r.f64() - 0.2) * 4.0,
                seed: r.next_u64(),
                anneal: r.bool(0.5),
                dual_threshold: r.bool(0.5),
            },
            |p| match p.validate() {
                Err(_) => true,
                Ok(()) => {
                    let mut obj = FlatPlateau::new(3);
                    let h = KmeansTpe::new(*p).run(&mut obj, 12);
                    h.len() == 12
                }
            },
        );
    }
}
