//! Batched proposal + parallel evaluation engine.
//!
//! The paper's headline claim is search-time reduction, and the expensive
//! part of every search iteration is the objective (a proxy-QAT run). The
//! sequential `Searcher` loop leaves parallel hardware idle between
//! proposals; [`BatchSearcher`] instead proposes `q` candidates per round
//! with the constant-liar strategy (pending proposals are pessimistically
//! imputed into g(x), so the round diversifies instead of collapsing onto
//! one acquisition mode) and hands the whole round to
//! [`Objective::eval_batch`] — which a parallel or remote objective spreads
//! across threads / worker processes. Search wall-clock then scales with
//! worker count while the *evaluation-count* convergence stays comparable
//! to the sequential searcher (see tests). With [`QPolicy::Auto`] the batch
//! size itself is tuned online between 1 and the objective's parallelism
//! from the observed eval/proposal cost ratio (see [`QController`] docs) —
//! a ratio the table-driven Parzen proposal path (log-prob + threshold
//! tables, see `search::parzen`) and the coordinator's binary v4 eval
//! framing (delta-coded configs, see `coordinator::wire`) both shift toward
//! larger useful q by cutting per-proposal and per-eval overhead.
//!
//! Also here:
//! * [`eval_batch_parallel`] / [`ParallelObjective`] — thread-parallel batch
//!   evaluation over per-thread objective replicas (for `Send` objectives:
//!   mlbase hyperparameter objectives, synthetic functions, hw-model-only
//!   evaluations — PJRT-backed objectives stay process-parallel via the
//!   coordinator service).
//! * [`CachedObjective`] — a config-keyed eval cache; duplicate proposals
//!   (common on small pruned spaces) skip the expensive re-evaluation.

use std::collections::HashMap;

use super::checkpoint::{RngState, SearchCheckpoint};
use super::costmodel::CostModel;
use super::history::History;
use super::kmeans_tpe::{KmeansTpeParams, KmeansTpeState};
use super::space::{Config, Space};
use super::tpe::{TpeParams, TpeState};
use super::{Objective, Searcher};
use crate::util::rng::Rng;
use crate::util::Timer;

/// Which proposal strategy a [`BatchSearcher`] drives.
#[derive(Debug, Clone, Copy)]
pub enum BatchAlgo {
    KmeansTpe(KmeansTpeParams),
    Tpe(TpeParams),
}

enum ProposerState {
    Km(KmeansTpeState),
    Tpe(TpeState),
}

impl ProposerState {
    fn observe(&mut self, config: Config, value: f64) {
        match self {
            ProposerState::Km(s) => s.observe(config, value),
            ProposerState::Tpe(s) => s.observe(config, value),
        }
    }

    fn propose_batch(&mut self, q: usize, rng: &mut Rng) -> Vec<Config> {
        match self {
            ProposerState::Km(s) => s.propose_batch(q, rng),
            ProposerState::Tpe(s) => s.propose_batch(q, rng),
        }
    }

    /// (annealing rounds, warm centroids) for a checkpoint. TPE's ordering
    /// is replayable from the history alone, so it contributes nothing.
    fn snapshot(&self) -> (usize, Vec<f64>) {
        match self {
            ProposerState::Km(s) => (s.rounds(), s.warm_centroids().to_vec()),
            ProposerState::Tpe(_) => (0, Vec::new()),
        }
    }

    fn restore(
        algo: BatchAlgo,
        space: Space,
        ck: &SearchCheckpoint,
    ) -> ProposerState {
        let configs: Vec<Config> =
            ck.history.trials.iter().map(|t| t.config.clone()).collect();
        let values: Vec<f64> = ck.history.trials.iter().map(|t| t.value).collect();
        match algo {
            BatchAlgo::KmeansTpe(p) => ProposerState::Km(KmeansTpeState::restore(
                p,
                space,
                configs,
                values,
                ck.iter,
                ck.centroids.clone(),
            )),
            BatchAlgo::Tpe(p) => {
                ProposerState::Tpe(TpeState::restore(p, space, configs, values))
            }
        }
    }
}

/// Batch-size policy of a [`BatchSearcher`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QPolicy {
    /// Always propose `q` per round (q = 1 is the sequential loop).
    Fixed(usize),
    /// Tune q online in [1, `Objective::parallelism()`]: track the observed
    /// eval-time / proposal-time ratio and the constant-liar
    /// diversification, so cheap objectives degrade to sequential TPE
    /// (maximal surrogate freshness) and expensive ones keep the pool
    /// saturated. See [`QController`].
    Auto,
}

impl QPolicy {
    /// Parse a `--batch-q` style setting: a number, or `auto`. Zero is
    /// clamped to the sequential loop.
    pub fn parse(s: &str) -> Option<QPolicy> {
        if s.eq_ignore_ascii_case("auto") {
            return Some(QPolicy::Auto);
        }
        s.parse::<usize>().ok().map(|q| QPolicy::Fixed(q.max(1)))
    }

    /// Does this setting ask for batched rounds at all?
    pub fn batched(self) -> bool {
        !matches!(self, QPolicy::Fixed(0) | QPolicy::Fixed(1))
    }
}

/// One evaluation round as logged by [`BatchSearcher`] — q decisions are
/// verified against this by the adaptive-q tests.
#[derive(Debug, Clone, Copy)]
pub struct RoundStat {
    /// Proposals this round actually made (<= chosen q at the budget tail).
    pub q: usize,
    /// Distinct configs among them (constant-liar diversification).
    pub distinct: usize,
    /// Wall-clock spent proposing the round.
    pub propose_secs: f64,
    /// Wall-clock spent in `eval_batch`.
    pub eval_secs: f64,
    /// Whether this was a random-startup round.
    pub startup: bool,
}

/// Online q tuner. The tradeoff it walks: larger q amortizes proposal
/// overhead and fills parallel evaluators, but each constant-liar round is
/// proposed from a STALE surrogate, so q should only grow while (a)
/// evaluations dominate proposals and (b) the liar still diversifies.
///
///   q* = clamp(floor(predicted_secs_per_EVALUATION / secs_per_PROPOSAL),
///              1, parallelism)
///
/// The evaluation side is PROACTIVE: it comes from the per-config linear
/// [`CostModel`] the run fits from `eval_batch_timed` observations,
/// evaluated at the feature mean of the region the search currently
/// occupies — not from a reactive EWMA of whatever the last rounds
/// happened to cost (the PR 2 controller this replaces; the wave-count
/// normalization that controller needed is gone too, because per-config
/// timings are already independent of the controller's own q choice). The
/// proposal side stays an EWMA of measured per-proposal cost, and the
/// result is capped by the smoothed distinct-per-round FRACTION of
/// capacity (proposing more copies of the same argmax than the liar can
/// spread wastes evaluations — and a fraction, unlike an absolute count,
/// lets q recover after a throttled phase, since distinct/q is 1.0 at
/// q = 1). An instant objective drives the ratio below 1 and q settles at
/// 1; an objective that costs even a few ms against a sub-ms proposal path
/// drives q to the pool capacity.
struct QController {
    prop_per: crate::util::timer::Ewma,
    /// EWMA of distinct/q per round — a FRACTION, not an absolute count:
    /// distinct is bounded by q, so an absolute EWMA would ratchet q
    /// downward with no way back (rounds at small q can only report small
    /// distinct counts). The fraction is 1.0 at q = 1, so a throttled
    /// controller re-earns its capacity as soon as rounds diversify again.
    distinct_frac: crate::util::timer::Ewma,
}

impl QController {
    fn new() -> QController {
        QController {
            prop_per: crate::util::timer::Ewma::new(0.5),
            distinct_frac: crate::util::timer::Ewma::new(0.5),
        }
    }

    fn observe(&mut self, stat: &RoundStat) {
        let m = stat.q.max(1);
        // Startup rounds sample at random — far cheaper than a TPE
        // proposal — and would make proposals look free; only model-based
        // rounds inform the proposal-cost side. Proposals are sequential,
        // so per-proposal cost divides by m.
        if !stat.startup {
            self.prop_per.observe(stat.propose_secs / m as f64);
        }
        self.distinct_frac.observe(stat.distinct as f64 / m as f64);
    }

    fn next_q(&self, cap: usize, cost: &CostModel) -> usize {
        let cap = cap.max(1);
        let (Some(eval), Some(prop)) = (cost.predicted_mean(), self.prop_per.value())
        else {
            // No fitted cost model or no model-based round measured yet:
            // stay saturated, the startup phase is embarrassingly parallel
            // anyway.
            return cap;
        };
        let ratio = eval / prop.max(1e-9);
        let mut q = if ratio.is_finite() { ratio.floor().max(1.0) as usize } else { cap };
        q = q.min(cap);
        // Diversification cap: no point proposing more of the round than
        // the liar has been spreading (fraction of cap, see field docs).
        let spread =
            (self.distinct_frac.value_or(1.0) * cap as f64).ceil().max(1.0) as usize;
        q.min(spread)
    }
}

/// Round-based searcher: proposes `q` configs per round (constant liar),
/// evaluates them through [`Objective::eval_batch`], then folds the real
/// values back into the surrogate state. With q = 1 it degenerates to the
/// sequential searcher (modulo RNG stream). `QPolicy::Auto` re-tunes q
/// between rounds; every round is appended to [`rounds`](Self::rounds).
pub struct BatchSearcher {
    pub algo: BatchAlgo,
    /// Batch-size policy (the paper-style "q" of batched BO).
    pub q: QPolicy,
    /// Round log of the last `run` (cleared at the start of each run).
    pub rounds: Vec<RoundStat>,
}

impl BatchSearcher {
    pub fn new(algo: BatchAlgo, q: QPolicy) -> BatchSearcher {
        BatchSearcher { algo, q, rounds: Vec::new() }
    }

    pub fn kmeans_tpe(params: KmeansTpeParams, q: usize) -> BatchSearcher {
        BatchSearcher::new(BatchAlgo::KmeansTpe(params), QPolicy::Fixed(q))
    }

    pub fn tpe(params: TpeParams, q: usize) -> BatchSearcher {
        BatchSearcher::new(BatchAlgo::Tpe(params), QPolicy::Fixed(q))
    }

    /// Adaptive-q flavors: q tracks the objective's cost and parallelism.
    pub fn kmeans_tpe_auto(params: KmeansTpeParams) -> BatchSearcher {
        BatchSearcher::new(BatchAlgo::KmeansTpe(params), QPolicy::Auto)
    }

    pub fn tpe_auto(params: TpeParams) -> BatchSearcher {
        BatchSearcher::new(BatchAlgo::Tpe(params), QPolicy::Auto)
    }

    fn seed_and_startup(&self) -> (u64, usize) {
        match self.algo {
            BatchAlgo::KmeansTpe(p) => (p.seed, p.n_startup),
            BatchAlgo::Tpe(p) => (p.seed, p.n_startup),
        }
    }

    fn algo_name(&self) -> &'static str {
        match self.algo {
            BatchAlgo::KmeansTpe(_) => "batch-kmeans-tpe",
            BatchAlgo::Tpe(_) => "batch-tpe",
        }
    }

    /// Open a stepwise run: [`BatchRun::step`] executes one proposal round
    /// at a time, so a caller can act BETWEEN rounds — write a session
    /// checkpoint, read the objective's record log — without aliasing the
    /// objective borrow a closed `run` loop would hold. With
    /// `resume: Some(ck)` the run continues a checkpointed search: restored
    /// history counts toward `budget`, the proposer warm-starts from the
    /// checkpointed (annealing round, centroids), and the RNG cursor picks
    /// up mid-stream — for fixed-q policies the remaining trials are exactly
    /// the ones the interrupted run would have produced. Errors when the
    /// checkpoint belongs to a different proposer or space width.
    pub fn start(
        &self,
        space: Space,
        budget: usize,
        resume: Option<&SearchCheckpoint>,
    ) -> anyhow::Result<BatchRun> {
        let (seed, n_startup) = self.seed_and_startup();
        let name = self.algo_name();
        let (state, rng, hist) = match resume {
            None => {
                let state = match self.algo {
                    BatchAlgo::KmeansTpe(p) => {
                        ProposerState::Km(KmeansTpeState::new(p, space.clone()))
                    }
                    BatchAlgo::Tpe(p) => ProposerState::Tpe(TpeState::new(p, space.clone())),
                };
                (state, Rng::new(seed ^ 0xBA7C4), History::new(name))
            }
            Some(ck) => {
                anyhow::ensure!(
                    ck.algo == name,
                    "checkpoint was taken by '{}', this searcher is '{name}'",
                    ck.algo
                );
                // Fingerprints, not dim counts: a re-pruned space with the
                // SAME width presents different menus, and replaying stored
                // choice indices against them silently reinterprets every
                // trial (the bug the old `ck.dims == num_dims()` guard let
                // through). A mismatched checkpoint must be projected first
                // — see `search::project::SpaceProjection`.
                let (ck_fp, fp) = (ck.space.fingerprint(), space.fingerprint());
                anyhow::ensure!(
                    ck_fp == fp,
                    "checkpoint space (fingerprint {ck_fp}, {} dims) does not match this \
                     run's space (fingerprint {fp}, {} dims): the menus differ, and the \
                     checkpoint's choice indices would be reinterpreted against the wrong \
                     values — project the history onto the new space first \
                     (--resume-project nearest|strict)",
                    ck.space.num_dims(),
                    space.num_dims()
                );
                let state = ProposerState::restore(self.algo, space.clone(), ck);
                (state, ck.rng.to_rng(), ck.history.clone())
            }
        };
        // The cost model always starts cold — even on resume. Its
        // observations are wall-clock measurements of THIS machine's
        // evaluator, which a checkpoint from another run (or another pool)
        // has no authority over; like adaptive q itself, scheduling is
        // re-learned in a couple of rounds.
        let cost = CostModel::for_space(&space);
        Ok(BatchRun {
            algo_name: name,
            policy: self.q,
            space,
            state,
            rng,
            hist,
            ctl: QController::new(),
            cost,
            q: None,
            n0: n_startup.min(budget),
            budget,
            rounds: Vec::new(),
        })
    }

    /// Open a run whose surrogates are PRE-SEEDED with transferred history
    /// (the `--warehouse` warm start). The seeds feed the proposer exactly
    /// as restored trials would, but — unlike `resume` — they never enter
    /// the run's own history and do not count toward `budget`: the session
    /// still runs its full budget of evaluations, served from the eval
    /// cache wherever a seed already paid for them. The random-startup
    /// phase shrinks by the seed count (the seeds ARE startup evidence),
    /// and the RNG is the fresh-start stream, so a zero-seed warm start is
    /// bit-identical to a cold [`start`](Self::start). Seeds must be valid
    /// for `space` — cross-space warehouse histories are projected before
    /// they get here (`search::warehouse`).
    pub fn start_warm(
        &self,
        space: Space,
        budget: usize,
        seed_configs: Vec<Config>,
        seed_values: Vec<f64>,
    ) -> anyhow::Result<BatchRun> {
        anyhow::ensure!(
            seed_configs.len() == seed_values.len(),
            "warm start: {} seed configs for {} values",
            seed_configs.len(),
            seed_values.len()
        );
        if seed_configs.is_empty() {
            return self.start(space, budget, None);
        }
        for c in &seed_configs {
            anyhow::ensure!(
                space.validate(c),
                "warm start: seed config {c:?} is invalid for this space — project \
                 the stored history onto it first (--warm-start nearest|strict)"
            );
        }
        let (seed, n_startup) = self.seed_and_startup();
        let name = self.algo_name();
        let n_seeds = seed_configs.len();
        let cost = CostModel::for_space(&space);
        let state = match self.algo {
            BatchAlgo::KmeansTpe(p) => ProposerState::Km(KmeansTpeState::restore(
                p,
                space.clone(),
                seed_configs,
                seed_values,
                0,
                Vec::new(),
            )),
            BatchAlgo::Tpe(p) => ProposerState::Tpe(TpeState::restore(
                p,
                space.clone(),
                seed_configs,
                seed_values,
            )),
        };
        Ok(BatchRun {
            algo_name: name,
            policy: self.q,
            space,
            state,
            rng: Rng::new(seed ^ 0xBA7C4),
            hist: History::new(name),
            ctl: QController::new(),
            cost,
            q: None,
            n0: n_startup.saturating_sub(n_seeds).min(budget),
            budget,
            rounds: Vec::new(),
        })
    }
}

/// An in-flight batched search (see [`BatchSearcher::start`]).
pub struct BatchRun {
    algo_name: &'static str,
    policy: QPolicy,
    space: Space,
    state: ProposerState,
    rng: Rng,
    hist: History,
    ctl: QController,
    /// Per-config eval-cost model fit from `eval_batch_timed` observations;
    /// drives proactive q and the longest-job-first round ordering.
    cost: CostModel,
    /// Next round's batch size; `None` until the first step reads the
    /// objective's parallelism (Auto starts saturated: until the first
    /// model-based round is measured there is no reason to idle evaluators).
    q: Option<usize>,
    n0: usize,
    budget: usize,
    /// Round log so far (becomes `BatchSearcher::rounds` after a closed run).
    pub rounds: Vec<RoundStat>,
}

impl BatchRun {
    pub fn done(&self) -> bool {
        self.hist.len() >= self.budget
    }

    pub fn history(&self) -> &History {
        &self.hist
    }

    /// Execute one proposal + evaluation round; no-op once the budget is
    /// spent. Startup rounds use random configs but still go through
    /// `eval_batch`, so a parallel objective saturates its workers from
    /// round one.
    pub fn step(&mut self, obj: &mut dyn Objective) -> Option<RoundStat> {
        if self.done() {
            return None;
        }
        let q = match self.q {
            Some(q) => q,
            None => {
                let q = match self.policy {
                    QPolicy::Fixed(q) => q.max(1),
                    QPolicy::Auto => obj.parallelism().max(1),
                };
                self.q = Some(q);
                q
            }
        };
        let m = q.min(self.budget - self.hist.len());
        let startup = self.hist.len() < self.n0;
        let t_prop = Timer::start();
        let mut batch: Vec<Config> = if startup {
            let m0 = m.min(self.n0 - self.hist.len());
            (0..m0).map(|_| self.space.sample(&mut self.rng)).collect()
        } else {
            self.state.propose_batch(m, &mut self.rng)
        };
        // Longest-job-first: once the cost model is fitted, hand the round
        // to the evaluator ordered by predicted cost DESCENDING, so under
        // work stealing the expensive evaluations start first and the cheap
        // ones backfill idle workers — instead of an expensive config
        // starting last and stalling the round tail alone. Only the
        // adaptive policy reorders: its schedule is wall-clock-driven and
        // was never replay-reproducible, while fixed-q runs promise
        // bit-identical histories (determinism + checkpoint-resume tests).
        // A remote pool additionally orders its own shared queue from its
        // per-session model (covering fixed-q and multi-tenant callers);
        // both models learn the same latencies, so the two sorts agree —
        // this one exists for in-process parallel objectives that have no
        // pool underneath.
        if self.policy == QPolicy::Auto && self.cost.ready() && batch.len() > 1 {
            let pred: Vec<f64> =
                batch.iter().map(|c| self.cost.predict(c).unwrap_or(0.0)).collect();
            let mut idx: Vec<usize> = (0..batch.len()).collect();
            idx.sort_by(|&a, &b| pred[b].total_cmp(&pred[a]).then(a.cmp(&b)));
            batch = idx.into_iter().map(|i| std::mem::take(&mut batch[i])).collect();
        }
        let propose_secs = t_prop.secs();
        let distinct = batch.iter().collect::<std::collections::HashSet<&Config>>().len();
        let t = Timer::start();
        let (values, eval_times) = obj.eval_batch_timed(&batch);
        let eval_secs = t.secs();
        debug_assert_eq!(values.len(), batch.len(), "eval_batch_timed length mismatch");
        debug_assert_eq!(eval_times.len(), batch.len(), "eval_batch_timed times mismatch");
        // Per-trial timing is the round's wall-clock amortized over the
        // batch: total_eval_secs stays the true wall-clock spent. The
        // per-config timings go to the cost model instead, which wants
        // worker-side service time, not leader wall.
        let per = eval_secs / batch.len().max(1) as f64;
        let stat = RoundStat { q: batch.len(), distinct, propose_secs, eval_secs, startup };
        for ((config, value), secs) in batch.into_iter().zip(values).zip(eval_times) {
            self.cost.observe(&config, secs);
            self.hist.push(config.clone(), value, per);
            self.state.observe(config, value);
        }
        // Re-read capacity every round: a remote pool can lose (or
        // regain) workers mid-search, and the clamp must track the LIVE
        // count — a stale snapshot would keep q pinned above what the pool
        // can actually run.
        let cap = obj.parallelism().max(1);
        self.ctl.observe(&stat);
        self.rounds.push(stat);
        if self.policy == QPolicy::Auto {
            self.q = Some(self.ctl.next_q(cap, &self.cost));
        }
        Some(stat)
    }

    /// The run's fitted per-config cost model (scheduling introspection).
    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// Freeze the run at the current round boundary. The checkpoint carries
    /// the full space (menus included), so resume can verify fingerprints —
    /// and projection can remap the history when the space legitimately
    /// changed.
    pub fn checkpoint(&self) -> SearchCheckpoint {
        let (iter, centroids) = self.state.snapshot();
        SearchCheckpoint {
            algo: self.algo_name.to_string(),
            space: self.space.clone(),
            history: self.hist.clone(),
            iter,
            centroids,
            rng: RngState::of(&self.rng),
        }
    }

    pub fn finish(self) -> (History, Vec<RoundStat>) {
        (self.hist, self.rounds)
    }
}

impl Searcher for BatchSearcher {
    fn name(&self) -> &'static str {
        self.algo_name()
    }

    fn run(&mut self, obj: &mut dyn Objective, budget: usize) -> History {
        let mut run = self
            .start(obj.space().clone(), budget, None)
            .expect("a fresh batch run has no checkpoint to mismatch");
        while !run.done() {
            run.step(obj);
        }
        let (hist, rounds) = run.finish();
        self.rounds = rounds;
        hist
    }
}

// ---------------------------------------------------------------------------
// Thread-parallel batch evaluation
// ---------------------------------------------------------------------------

/// Evaluate `configs` across a pool of independent objective replicas, one
/// thread per replica (round-robin sharding: replica w takes configs w,
/// w + W, w + 2W, ...). Returns values in input order.
///
/// Replicas must be behaviorally identical (same space, same response to a
/// config) — typically the same constructor called once per worker. The
/// objectives only need `Send`, not `Sync`, since each replica is moved into
/// exactly one thread.
pub fn eval_batch_parallel<O: Objective + Send>(
    replicas: &mut [O],
    configs: &[Config],
) -> Vec<f64> {
    eval_batch_parallel_timed(replicas, configs).0
}

/// [`eval_batch_parallel`] plus each config's own evaluation wall-clock,
/// measured inside its worker thread — true per-config service time, not
/// the round wall amortized (which would shrink with the thread count and
/// blind the scheduler's cost model to config-dependent costs).
pub fn eval_batch_parallel_timed<O: Objective + Send>(
    replicas: &mut [O],
    configs: &[Config],
) -> (Vec<f64>, Vec<f64>) {
    assert!(!replicas.is_empty(), "eval_batch_parallel: no objective replicas");
    if configs.is_empty() {
        return (Vec::new(), Vec::new());
    }
    let workers = replicas.len().min(configs.len());
    if workers == 1 {
        return replicas[0].eval_batch_timed(configs);
    }
    let mut out = vec![f64::NAN; configs.len()];
    let mut secs = vec![0.0; configs.len()];
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for (w, replica) in replicas.iter_mut().take(workers).enumerate() {
            handles.push(scope.spawn(move || {
                configs
                    .iter()
                    .enumerate()
                    .skip(w)
                    .step_by(workers)
                    .map(|(i, c)| {
                        let t = std::time::Instant::now();
                        let v = replica.eval(c);
                        (i, v, t.elapsed().as_secs_f64())
                    })
                    .collect::<Vec<(usize, f64, f64)>>()
            }));
        }
        for handle in handles {
            for (i, v, s) in handle.join().expect("evaluation thread panicked") {
                out[i] = v;
                secs[i] = s;
            }
        }
    });
    (out, secs)
}

/// An [`Objective`] whose `eval_batch` fans out over thread-local replicas.
/// Sequential `eval` goes to replica 0, so a `BatchSearcher` driving this
/// wrapper gets thread parallelism with zero further wiring.
pub struct ParallelObjective<O: Objective + Send> {
    pub replicas: Vec<O>,
}

impl<O: Objective + Send> ParallelObjective<O> {
    pub fn new(replicas: Vec<O>) -> ParallelObjective<O> {
        assert!(!replicas.is_empty(), "ParallelObjective needs at least one replica");
        ParallelObjective { replicas }
    }
}

impl<O: Objective + Send> Objective for ParallelObjective<O> {
    fn space(&self) -> &Space {
        self.replicas[0].space()
    }

    fn eval(&mut self, config: &Config) -> f64 {
        self.replicas[0].eval(config)
    }

    fn eval_batch(&mut self, configs: &[Config]) -> Vec<f64> {
        eval_batch_parallel(&mut self.replicas, configs)
    }

    fn eval_batch_timed(&mut self, configs: &[Config]) -> (Vec<f64>, Vec<f64>) {
        eval_batch_parallel_timed(&mut self.replicas, configs)
    }

    fn parallelism(&self) -> usize {
        self.replicas.len()
    }
}

// ---------------------------------------------------------------------------
// Config-keyed evaluation cache
// ---------------------------------------------------------------------------

/// Default capacity of the config-keyed eval caches (this wrapper and the
/// record-level cache inside `DnnObjective`). Generous against any single
/// session's budget — a 40-eval search never evicts — but a hard ceiling
/// for the long-lived, warehouse-seeded leaders that used to grow these
/// maps without bound.
pub const EVAL_CACHE_CAP: usize = 8192;

/// Memoizes an inner objective by exact config. Duplicate proposals — common
/// once TPE concentrates on a small pruned space, and likelier still in
/// batched rounds — skip the inner evaluation entirely. The DNN objective
/// additionally maintains its own record-level cache (it logs full
/// `EvalRecord`s); this wrapper serves every other objective. The cache is
/// bounded ([`EVAL_CACHE_CAP`] by default) with deterministic FIFO
/// eviction in insertion order — no clocks, so replays evict identically.
pub struct CachedObjective<O: Objective> {
    pub inner: O,
    cache: HashMap<Config, f64>,
    /// Insertion order, for FIFO eviction once `cap` is reached.
    order: std::collections::VecDeque<Config>,
    cap: usize,
    pub hits: usize,
    pub misses: usize,
    pub evictions: usize,
}

impl<O: Objective> CachedObjective<O> {
    pub fn new(inner: O) -> CachedObjective<O> {
        CachedObjective::with_capacity(inner, EVAL_CACHE_CAP)
    }

    /// Cache bounded to `cap` entries (clamped to at least 1).
    pub fn with_capacity(inner: O, cap: usize) -> CachedObjective<O> {
        CachedObjective {
            inner,
            cache: HashMap::new(),
            order: std::collections::VecDeque::new(),
            cap: cap.max(1),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Insert a finite value, evicting the oldest entry at capacity.
    fn remember(&mut self, config: &Config, v: f64) {
        if !v.is_finite() || self.cache.contains_key(config) {
            return;
        }
        if self.cache.len() >= self.cap {
            if let Some(old) = self.order.pop_front() {
                self.cache.remove(&old);
                self.evictions += 1;
            }
        }
        self.cache.insert(config.clone(), v);
        self.order.push_back(config.clone());
    }

    /// Pre-populate from already-paid (config, value) pairs — the
    /// warehouse exact-hit path. Non-finite values and configs invalid for
    /// the inner space are skipped; returns how many entries went in.
    pub fn seed(&mut self, entries: &[(Config, f64)]) -> usize {
        let mut added = 0;
        for (c, v) in entries {
            if v.is_finite()
                && self.inner.space().validate(c)
                && !self.cache.contains_key(c)
            {
                self.remember(c, *v);
                added += 1;
            }
        }
        added
    }
}

impl<O: Objective> Objective for CachedObjective<O> {
    fn space(&self) -> &Space {
        self.inner.space()
    }

    fn eval(&mut self, config: &Config) -> f64 {
        if let Some(&v) = self.cache.get(config) {
            self.hits += 1;
            return v;
        }
        let v = self.inner.eval(config);
        self.misses += 1;
        // Failure sentinels (NaN from a crashed replica, -inf from a remote
        // worker hiccup) are served this once but never pinned into the
        // cache — mirroring DnnObjective's refusal to cache failed evals.
        self.remember(config, v);
        v
    }

    fn eval_batch(&mut self, configs: &[Config]) -> Vec<f64> {
        self.eval_batch_timed(configs).0
    }

    fn eval_batch_timed(&mut self, configs: &[Config]) -> (Vec<f64>, Vec<f64>) {
        // Evaluate only the unique cache misses through the inner batch path
        // (so a parallel/remote inner objective still sees one batch), then
        // fill every slot — including intra-batch duplicates — from this
        // round's values. Cache hits report a zero cost — truthfully: a hit
        // IS free, and a cost model that learns hits are free correctly
        // stops budgeting wall-clock for repeat proposals.
        let mut out = vec![f64::NAN; configs.len()];
        let mut secs = vec![0.0; configs.len()];
        let mut pending: Vec<usize> = Vec::new();
        let mut miss_cfg: Vec<Config> = Vec::new();
        // Config -> position in miss_cfg, for intra-batch duplicates.
        let mut miss_at: std::collections::HashMap<&Config, usize> =
            std::collections::HashMap::new();
        for (i, c) in configs.iter().enumerate() {
            if let Some(&v) = self.cache.get(c) {
                self.hits += 1;
                out[i] = v;
            } else {
                if miss_at.contains_key(c) {
                    self.hits += 1;
                } else {
                    miss_at.insert(c, miss_cfg.len());
                    miss_cfg.push(c.clone());
                    self.misses += 1;
                }
                pending.push(i);
            }
        }
        if !miss_cfg.is_empty() {
            let (values, times) = self.inner.eval_batch_timed(&miss_cfg);
            debug_assert_eq!(values.len(), miss_cfg.len(), "eval_batch length mismatch");
            for (c, &v) in miss_cfg.iter().zip(&values) {
                // As in eval(): non-finite results are not cached.
                self.remember(c, v);
            }
            for i in pending {
                let at = miss_at[&configs[i]];
                out[i] = values[at];
                secs[i] = times[at];
            }
        }
        (out, secs)
    }

    fn parallelism(&self) -> usize {
        self.inner.parallelism()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::space::Dim;
    use crate::search::{KmeansTpe, SyntheticObjective, Tpe};

    /// Deterministic separable objective counting its evaluations.
    struct Sep {
        space: Space,
        evals: usize,
    }

    impl Sep {
        fn new(dims: usize) -> Sep {
            Sep {
                space: Space::new(
                    (0..dims)
                        .map(|d| Dim::new(format!("d{d}"), vec![0.0, 1.0, 2.0, 3.0]))
                        .collect(),
                ),
                evals: 0,
            }
        }
    }

    impl Objective for Sep {
        fn space(&self) -> &Space {
            &self.space
        }
        fn eval(&mut self, c: &Config) -> f64 {
            self.evals += 1;
            -(c.iter().map(|&x| x as f64).sum::<f64>())
        }
    }

    /// The FlatPlateau landscape of the kmeans_tpe tests (private there).
    struct FlatPlateau {
        space: Space,
    }

    impl FlatPlateau {
        fn new(dims: usize) -> FlatPlateau {
            FlatPlateau {
                space: Space::new(
                    (0..dims)
                        .map(|d| Dim::new(format!("d{d}"), vec![0.0, 1.0, 2.0, 3.0]))
                        .collect(),
                ),
            }
        }
    }

    impl Objective for FlatPlateau {
        fn space(&self) -> &Space {
            &self.space
        }
        fn eval(&mut self, config: &Config) -> f64 {
            let good = config.iter().filter(|&&c| c == 0).count() as f64;
            let frac = good / config.len() as f64;
            if frac >= 0.95 {
                1.0
            } else if frac >= 0.5 {
                0.8 + 0.001 * frac
            } else {
                0.5 + 0.001 * frac
            }
        }
    }

    #[test]
    fn batch_run_respects_budget_and_is_deterministic() {
        let p = KmeansTpeParams { n_startup: 8, seed: 3, ..Default::default() };
        let h1 = BatchSearcher::kmeans_tpe(p, 4).run(&mut Sep::new(5), 30);
        let h2 = BatchSearcher::kmeans_tpe(p, 4).run(&mut Sep::new(5), 30);
        assert_eq!(h1.len(), 30);
        assert_eq!(h1.values(), h2.values());
        assert_eq!(
            h1.trials.iter().map(|t| t.config.clone()).collect::<Vec<_>>(),
            h2.trials.iter().map(|t| t.config.clone()).collect::<Vec<_>>()
        );
        // Tpe flavor too, with a budget that is not a multiple of q.
        let tp = TpeParams { n_startup: 6, seed: 1, ..Default::default() };
        let h3 = BatchSearcher::tpe(tp, 4).run(&mut Sep::new(5), 23);
        assert_eq!(h3.len(), 23);
    }

    #[test]
    fn constant_liar_diversifies_the_round() {
        // A strongly peaked state: without the liar, every proposal in the
        // round would be the same argmax mode w.h.p.
        let space = Space::new(vec![
            Dim::new("a", vec![0.0, 1.0, 2.0]),
            Dim::new("b", vec![0.0, 1.0, 2.0]),
        ]);
        let mut state =
            TpeState::new(TpeParams { n_candidates: 64, ..Default::default() }, space);
        state.observe(vec![0, 0], 1.0); // the single "good" trial -> l(x)
        state.observe(vec![1, 1], 0.0); // the single "bad" trial  -> g(x)
        let mut rng = Rng::new(9);
        let batch = state.propose_batch(6, &mut rng);
        assert_eq!(batch.len(), 6);
        let distinct: std::collections::HashSet<&Config> = batch.iter().collect();
        assert!(distinct.len() >= 2, "constant liar failed to diversify: {batch:?}");
    }

    #[test]
    fn eval_batch_matches_sequential_eval() {
        let mut obj = Sep::new(6);
        let space = obj.space().clone();
        let mut rng = Rng::new(7);
        let configs: Vec<Config> = (0..12).map(|_| space.sample(&mut rng)).collect();
        let batch = obj.eval_batch(&configs);
        let seq: Vec<f64> = configs.iter().map(|c| obj.eval(c)).collect();
        assert_eq!(batch, seq);

        // Thread-parallel path agrees too.
        let mut par = ParallelObjective::new((0..3).map(|_| Sep::new(6)).collect());
        assert_eq!(par.eval_batch(&configs), seq);
        assert_eq!(par.eval_batch(&[]), Vec::<f64>::new());
    }

    #[test]
    fn cached_objective_identical_values_and_skipped_evals() {
        let mut cached = CachedObjective::new(Sep::new(4));
        let a: Config = vec![0, 1, 2, 3];
        let b: Config = vec![3, 2, 1, 0];
        let va = cached.eval(&a);
        let vb = cached.eval(&b);
        assert_eq!(cached.inner.evals, 2);
        // Duplicates return identical values without touching the inner.
        assert_eq!(cached.eval(&a), va);
        assert_eq!(cached.eval(&b), vb);
        assert_eq!(cached.inner.evals, 2);
        assert_eq!(cached.hits, 2);

        // Batch path: mixed hits, misses, and an intra-batch duplicate.
        let c: Config = vec![1, 1, 1, 1];
        let batch = vec![a.clone(), c.clone(), c.clone(), b.clone()];
        let vals = cached.eval_batch(&batch);
        assert_eq!(vals[0], va);
        assert_eq!(vals[3], vb);
        assert_eq!(vals[1], vals[2]);
        assert_eq!(cached.inner.evals, 3); // only `c` was new
    }

    #[test]
    fn cache_does_not_pin_failure_sentinels() {
        struct Flaky {
            space: Space,
            fail_next: bool,
            evals: usize,
        }
        impl Objective for Flaky {
            fn space(&self) -> &Space {
                &self.space
            }
            fn eval(&mut self, _c: &Config) -> f64 {
                self.evals += 1;
                if std::mem::take(&mut self.fail_next) {
                    f64::NEG_INFINITY
                } else {
                    1.0
                }
            }
        }
        let mut cached = CachedObjective::new(Flaky {
            space: Space::new(vec![Dim::new("a", vec![0.0, 1.0])]),
            fail_next: true,
            evals: 0,
        });
        let c: Config = vec![0];
        // The transient failure is served once but not cached...
        assert_eq!(cached.eval(&c), f64::NEG_INFINITY);
        // ...so the retry re-evaluates, succeeds, and THAT value sticks.
        assert_eq!(cached.eval(&c), 1.0);
        assert_eq!(cached.eval(&c), 1.0);
        assert_eq!(cached.inner.evals, 2);

        // Batch path: same policy.
        let mut cached = CachedObjective::new(Flaky {
            space: Space::new(vec![Dim::new("a", vec![0.0, 1.0])]),
            fail_next: true,
            evals: 0,
        });
        assert_eq!(cached.eval_batch(&[c.clone()]), vec![f64::NEG_INFINITY]);
        assert_eq!(cached.eval_batch(&[c.clone()]), vec![1.0]);
        assert_eq!(cached.inner.evals, 2);
    }

    #[test]
    fn cache_is_bounded_with_fifo_eviction_and_seedable() {
        let mut cached = CachedObjective::with_capacity(Sep::new(2), 2);
        let (a, b, c): (Config, Config, Config) = (vec![0, 0], vec![1, 1], vec![2, 2]);
        cached.eval(&a);
        cached.eval(&b);
        assert_eq!(cached.evictions, 0);
        // Third insert evicts the OLDEST entry (a), deterministically.
        cached.eval(&c);
        assert_eq!(cached.evictions, 1);
        assert_eq!(cached.inner.evals, 3);
        cached.eval(&a); // evicted -> a real re-evaluation
        assert_eq!(cached.inner.evals, 4);
        cached.eval(&c); // still resident
        assert_eq!(cached.inner.evals, 4);

        // Warehouse-style seeding: finite + valid entries only, and a
        // seeded config is served without ever touching the inner.
        let mut seeded = CachedObjective::with_capacity(Sep::new(2), 8);
        let added = seeded.seed(&[
            (vec![0, 0], -0.5),
            (vec![1, 1], f64::NEG_INFINITY), // failure sentinel: skipped
            (vec![9, 9], 1.0),               // invalid config: skipped
            (vec![0, 0], -0.7),              // already seeded: skipped
        ]);
        assert_eq!(added, 1);
        assert_eq!(seeded.eval(&vec![0, 0]), -0.5);
        assert_eq!(seeded.inner.evals, 0, "seeded config must not re-pay");
        assert_eq!(seeded.hits, 1);
    }

    #[test]
    fn warm_start_seeds_surrogates_without_charging_budget() {
        let budget = 30;
        let p = KmeansTpeParams { n_startup: 8, seed: 3, ..Default::default() };
        let searcher = BatchSearcher::kmeans_tpe(p, 4);
        let space = Sep::new(5).space.clone();

        // Zero seeds: bit-identical to a cold start.
        let cold = {
            let mut run = searcher.start(space.clone(), budget, None).unwrap();
            let mut obj = Sep::new(5);
            while !run.done() {
                run.step(&mut obj);
            }
            run.finish().0
        };
        let zero = {
            let mut run =
                searcher.start_warm(space.clone(), budget, Vec::new(), Vec::new()).unwrap();
            let mut obj = Sep::new(5);
            while !run.done() {
                run.step(&mut obj);
            }
            run.finish().0
        };
        assert_eq!(cold.values(), zero.values());
        for (a, b) in cold.trials.iter().zip(&zero.trials) {
            assert_eq!(a.config, b.config);
        }

        // Seeded: a prior run's trials feed the surrogates, the history
        // starts EMPTY (seeds are not charged to the budget), and with
        // seeds >= n_startup the random-startup phase is skipped entirely.
        let seeds: Vec<(Config, f64)> =
            cold.trials.iter().map(|t| (t.config.clone(), t.value)).collect();
        let (cfgs, vals): (Vec<Config>, Vec<f64>) = seeds.into_iter().unzip();
        let mut run = searcher.start_warm(space.clone(), budget, cfgs, vals).unwrap();
        assert_eq!(run.history().len(), 0, "seeds must not enter the history");
        let mut obj = Sep::new(5);
        let first = run.step(&mut obj).unwrap();
        assert!(!first.startup, "seeded run must start model-based");
        while !run.done() {
            run.step(&mut obj);
        }
        let hist = run.finish().0;
        assert_eq!(hist.len(), budget, "warm run still pays its full budget");
        for t in &hist.trials {
            assert!(space.validate(&t.config));
        }

        // Both TPE flavors reject malformed seeds loudly.
        let err = searcher
            .start_warm(space.clone(), budget, vec![vec![0, 0, 0, 0, 0]], Vec::new())
            .unwrap_err();
        assert!(err.to_string().contains("seed configs"), "{err}");
        let err = searcher
            .start_warm(space.clone(), budget, vec![vec![99, 0, 0, 0, 0]], vec![0.5])
            .unwrap_err();
        assert!(err.to_string().contains("invalid"), "{err}");
        let tpe = BatchSearcher::tpe(
            TpeParams { n_startup: 8, seed: 3, ..Default::default() },
            4,
        );
        let err = tpe
            .start_warm(space, budget, vec![vec![99, 0, 0, 0, 0]], vec![0.5])
            .unwrap_err();
        assert!(err.to_string().contains("invalid"), "{err}");
    }

    #[test]
    fn batched_kmeans_tpe_matches_sequential_in_rounds() {
        // Acceptance criterion: batched KmeansTpe with q = 4 reaches the
        // same best objective (within one plateau) as the sequential
        // searcher on FlatPlateau, in no more ROUNDS than the sequential
        // searcher takes EVALUATIONS / 2. Medians over seeds.
        let budget = 120;
        let q = 4;
        let mut seq_evals = Vec::new();
        let mut batch_rounds = Vec::new();
        for seed in 0..5u64 {
            let p = KmeansTpeParams { n_startup: 20, seed, ..Default::default() };
            let hs = KmeansTpe::new(p).run(&mut FlatPlateau::new(8), budget);
            let seq_best = hs.best().unwrap().value;
            // Plateau floor one level below the sequential best.
            let target = if seq_best >= 1.0 {
                0.8
            } else if seq_best >= 0.8 {
                0.5
            } else {
                0.0
            };
            let se = hs.evals_to_reach(seq_best).unwrap_or(budget + 1);
            seq_evals.push(se as f64);

            let hb = BatchSearcher::kmeans_tpe(p, q).run(&mut FlatPlateau::new(8), budget);
            let reach = hb.evals_to_reach(target).unwrap_or(budget + 1);
            batch_rounds.push(reach.div_ceil(q) as f64);
        }
        let med = |v: &[f64]| crate::util::stats::quantile(v, 0.5);
        assert!(
            med(&batch_rounds) <= (med(&seq_evals) / 2.0).max(1.0),
            "batch rounds {batch_rounds:?} vs sequential evals {seq_evals:?}"
        );
    }

    #[test]
    fn batch_tpe_beats_random_on_separable() {
        let budget = 60;
        let mut batch_sum = 0.0;
        let mut rand_sum = 0.0;
        for seed in 0..6u64 {
            let p = TpeParams { n_startup: 16, seed, ..Default::default() };
            let h = BatchSearcher::tpe(p, 4).run(&mut Sep::new(8), budget);
            batch_sum += h.best().unwrap().value;

            let mut rng = Rng::new(seed ^ 0x5EED);
            let mut obj = Sep::new(8);
            let space = obj.space().clone();
            rand_sum += (0..budget)
                .map(|_| {
                    let c = space.sample(&mut rng);
                    obj.eval(&c)
                })
                .fold(f64::NEG_INFINITY, f64::max);
        }
        assert!(batch_sum >= rand_sum, "batch {batch_sum} vs random {rand_sum}");
    }

    /// Advertises parallel capacity without thread overhead: isolates the
    /// adaptive-q controller's reaction to an instant objective from
    /// thread-spawn wall-clock, which would otherwise be measured as
    /// "evaluation cost".
    struct FakeParallel {
        inner: SyntheticObjective,
        cap: usize,
    }

    impl Objective for FakeParallel {
        fn space(&self) -> &Space {
            self.inner.space()
        }
        fn eval(&mut self, c: &Config) -> f64 {
            self.inner.eval(c)
        }
        fn parallelism(&self) -> usize {
            self.cap
        }
    }

    #[test]
    fn adaptive_q_converges_to_one_on_instant_objective() {
        // 4-way parallel capacity, but evaluations are instant: parallel
        // rounds buy nothing and cost surrogate freshness, so the
        // controller must settle at q = 1 once model-based rounds start.
        let p = TpeParams { n_startup: 8, seed: 2, ..Default::default() };
        let mut searcher = BatchSearcher::tpe_auto(p);
        let mut obj = FakeParallel {
            inner: SyntheticObjective::new(6, 4, std::time::Duration::ZERO),
            cap: 4,
        };
        let h = searcher.run(&mut obj, 48);
        assert_eq!(h.len(), 48);
        let model_rounds: Vec<&RoundStat> =
            searcher.rounds.iter().filter(|r| !r.startup).collect();
        assert!(model_rounds.len() >= 4, "too few model rounds: {}", model_rounds.len());
        // The first model-based round may still run at the saturated q (the
        // proposal cost is unmeasured until then); later rounds must be
        // dominated by q = 1 — a lone scheduler spike inside one timed eval
        // can legitimately bump a single EWMA decision, so demand a heavy
        // majority rather than unanimity.
        let tail = &model_rounds[1..];
        let sequential = tail.iter().filter(|r| r.q == 1).count();
        assert!(
            sequential * 4 >= tail.len() * 3 && sequential >= 1,
            "q=1 in {sequential}/{} model rounds — round log: {:?}",
            tail.len(),
            searcher.rounds
        );
    }

    #[test]
    fn adaptive_q_saturates_pool_on_slow_objective() {
        // Evaluations cost ~8ms against a microsecond proposal path: the
        // controller must keep the 4-replica pool saturated (q = capacity).
        let p = TpeParams { n_startup: 8, seed: 2, ..Default::default() };
        let mut searcher = BatchSearcher::tpe_auto(p);
        let mut obj = ParallelObjective::new(
            (0..4)
                .map(|_| SyntheticObjective::new(8, 4, std::time::Duration::from_millis(8)))
                .collect(),
        );
        let h = searcher.run(&mut obj, 40);
        assert_eq!(h.len(), 40);
        let model_rounds: Vec<&RoundStat> =
            searcher.rounds.iter().filter(|r| !r.startup).collect();
        assert!(model_rounds.len() >= 3, "round log: {:?}", searcher.rounds);
        // Drop the budget-tail round (clipped to the remainder); of the
        // rest, the pool must be saturated in the (heavy) majority of
        // rounds — a lone scheduler hiccup may dent one EWMA decision.
        let full = &model_rounds[..model_rounds.len() - 1];
        let saturated = full.iter().filter(|r| r.q == 4).count();
        assert!(
            saturated * 3 >= full.len() * 2 && saturated >= 1,
            "saturated {saturated}/{} — round log: {:?}",
            full.len(),
            searcher.rounds
        );
    }

    /// Mid-run checkpoint + resume must reproduce the uninterrupted run's
    /// history EXACTLY (configs and values), including a serde round-trip of
    /// the checkpoint — the acceptance criterion for resumable sessions.
    #[test]
    fn checkpoint_resume_reproduces_uninterrupted_history_exactly() {
        use crate::util::json::Json;
        for (label, searcher) in [
            (
                "kmeans",
                BatchSearcher::kmeans_tpe(
                    KmeansTpeParams { n_startup: 6, seed: 9, ..Default::default() },
                    3,
                ),
            ),
            (
                "tpe",
                BatchSearcher::tpe(
                    crate::search::TpeParams { n_startup: 6, seed: 9, ..Default::default() },
                    3,
                ),
            ),
        ] {
            let budget = 30;
            let mut obj = SyntheticObjective::new(5, 4, std::time::Duration::ZERO);
            let space = obj.space().clone();
            let full = {
                let mut run = searcher.start(space.clone(), budget, None).unwrap();
                while !run.done() {
                    run.step(&mut obj);
                }
                run.finish().0
            };

            // Interrupted run: stop somewhere past startup, checkpoint,
            // round-trip the checkpoint through JSON, resume to completion.
            let mut run = searcher.start(space.clone(), budget, None).unwrap();
            while run.history().len() < 12 {
                run.step(&mut obj);
            }
            let ck = run.checkpoint();
            drop(run); // the "kill"
            let ck = SearchCheckpoint::from_json(
                &Json::parse(&ck.to_json().to_string_pretty()).unwrap(),
            )
            .unwrap();
            let mut resumed = searcher.start(space, budget, Some(&ck)).unwrap();
            while !resumed.done() {
                resumed.step(&mut obj);
            }
            let res = resumed.finish().0;

            assert_eq!(res.len(), full.len(), "{label}: budget mismatch");
            assert_eq!(res.values(), full.values(), "{label}: values diverged");
            for (i, (a, b)) in res.trials.iter().zip(&full.trials).enumerate() {
                assert_eq!(a.config, b.config, "{label}: trial {i} config diverged");
            }
        }
    }

    #[test]
    fn resume_rejects_mismatched_checkpoints() {
        let km = BatchSearcher::kmeans_tpe(KmeansTpeParams::default(), 2);
        let space = SyntheticObjective::new(4, 3, std::time::Duration::ZERO)
            .space()
            .clone();
        let mut obj = SyntheticObjective::new(4, 3, std::time::Duration::ZERO);
        let mut run = km.start(space.clone(), 8, None).unwrap();
        run.step(&mut obj);
        let ck = run.checkpoint();
        // Wrong proposer family.
        let tp = BatchSearcher::tpe(crate::search::TpeParams::default(), 2);
        let err = tp.start(space.clone(), 8, Some(&ck)).unwrap_err();
        assert!(err.to_string().contains("batch-kmeans-tpe"), "{err}");
        // Wrong space width.
        let other = SyntheticObjective::new(6, 3, std::time::Duration::ZERO)
            .space()
            .clone();
        let err = km.start(other, 8, Some(&ck)).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        // REGRESSION (the silent-corruption bug): same dim count, same
        // widths, DIFFERENT menus — the old dim-count guard resumed this
        // and reinterpreted every stored index against the wrong values.
        // Now it is a hard structured error pointing at projection.
        let mut repruned = space;
        repruned.dims[0].choices = vec![8.0, 6.0, 4.0];
        assert_eq!(repruned.num_dims(), ck.space.num_dims());
        let err = km.start(repruned, 8, Some(&ck)).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        assert!(err.to_string().contains("resume-project"), "{err}");
        // A resume whose budget is already spent finishes immediately.
        let done = km
            .start(
                SyntheticObjective::new(4, 3, std::time::Duration::ZERO).space().clone(),
                ck.history.len(),
                Some(&ck),
            )
            .unwrap();
        assert!(done.done());
    }

    /// A fixed-q session checkpointed on space A and resumed (projected)
    /// onto a re-pruned space B must complete without error, with every
    /// projected trial valid in B, the report's counts summing to the
    /// checkpointed trial count, and a final incumbent matching a cold run
    /// on B within tolerance — the cross-space resume acceptance criterion.
    #[test]
    fn projected_resume_onto_repruned_space_matches_cold_run_incumbent() {
        use crate::search::project::{ProjectPolicy, SpaceProjection};
        use crate::search::space::Dim;

        // Menus whose values equal their indices, so the synthetic
        // landscape is identical under both spaces' decodings.
        let space_a = Space::new(
            (0..4).map(|d| Dim::new(format!("d{d}"), vec![0.0, 1.0, 2.0, 3.0])).collect(),
        );
        // Re-pruned: every dim loses its worst choice (same names).
        let space_b = Space::new(
            (0..4).map(|d| Dim::new(format!("d{d}"), vec![0.0, 1.0, 2.0])).collect(),
        );
        let budget = 60;
        let zero = std::time::Duration::ZERO;
        let p = KmeansTpeParams { n_startup: 10, seed: 21, ..Default::default() };
        let searcher = BatchSearcher::kmeans_tpe(p, 3);

        // Checkpoint mid-run on A.
        let mut obj_a = SyntheticObjective::with_space(space_a.clone(), zero);
        let mut run = searcher.start(space_a.clone(), budget, None).unwrap();
        while run.history().len() < 24 {
            run.step(&mut obj_a);
        }
        let ck = run.checkpoint();
        drop(run);

        // Project onto B and resume there.
        let proj = SpaceProjection::between(&space_a, &space_b);
        let out = proj.project_checkpoint(&ck, space_b.clone(), ProjectPolicy::Nearest);
        assert_eq!(out.report.total(), ck.history.len());
        assert_eq!(out.report.dropped, 0, "nearest never drops");
        assert!(out.report.snapped > 0, "startup sampling must have hit pruned choices");
        let mut obj_b = SyntheticObjective::with_space(space_b.clone(), zero);
        let mut resumed =
            searcher.start(space_b.clone(), budget, Some(&out.search)).unwrap();
        while !resumed.done() {
            resumed.step(&mut obj_b);
        }
        let resumed = resumed.finish().0;
        assert_eq!(resumed.len(), budget);
        for t in &resumed.trials {
            assert!(space_b.validate(&t.config), "trial escaped space B: {:?}", t.config);
        }

        // Cold reference on B.
        let mut obj_cold = SyntheticObjective::with_space(space_b.clone(), zero);
        let cold = {
            let mut run = searcher.start(space_b.clone(), budget, None).unwrap();
            while !run.done() {
                run.step(&mut obj_cold);
            }
            run.finish().0
        };
        let (rb, cb) = (resumed.best().unwrap().value, cold.best().unwrap().value);
        assert!(
            (rb - cb).abs() <= 2.0,
            "projected resume incumbent {rb} vs cold run {cb} diverged beyond tolerance"
        );

        // Strict flavor completes too; dropped trials re-earn budget.
        let strict = proj.project_checkpoint(&ck, space_b.clone(), ProjectPolicy::Strict);
        assert_eq!(strict.report.total(), ck.history.len());
        assert_eq!(
            strict.search.history.len(),
            strict.report.kept,
            "strict keeps only exact trials"
        );
        let mut obj_s = SyntheticObjective::with_space(space_b.clone(), zero);
        let mut srun =
            searcher.start(space_b.clone(), budget, Some(&strict.search)).unwrap();
        while !srun.done() {
            srun.step(&mut obj_s);
        }
        assert_eq!(srun.finish().0.len(), budget);
    }

    /// Failed (-inf) trials must ride through projection without poisoning
    /// the warm-started clustering or the resumed proposals.
    #[test]
    fn projected_resume_survives_neg_inf_trials() {
        use crate::search::project::{ProjectPolicy, SpaceProjection};
        use crate::search::space::Dim;

        /// -inf whenever dim 0 picks its upper half — covering both a
        /// choice that survives the re-prune (2) and one that does not (3).
        struct FailTail {
            space: Space,
        }
        impl Objective for FailTail {
            fn space(&self) -> &Space {
                &self.space
            }
            fn eval(&mut self, c: &Config) -> f64 {
                if c[0] >= 2 {
                    f64::NEG_INFINITY
                } else {
                    -(c.iter().sum::<usize>() as f64)
                }
            }
        }

        let space_a = Space::new(
            (0..3).map(|d| Dim::new(format!("d{d}"), vec![0.0, 1.0, 2.0, 3.0])).collect(),
        );
        let space_b = Space::new(
            (0..3).map(|d| Dim::new(format!("d{d}"), vec![0.0, 1.0, 2.0])).collect(),
        );
        let p = KmeansTpeParams { n_startup: 12, seed: 4, ..Default::default() };
        let searcher = BatchSearcher::kmeans_tpe(p, 3);
        let mut obj = FailTail { space: space_a.clone() };
        let mut run = searcher.start(space_a.clone(), 40, None).unwrap();
        while run.history().len() < 21 {
            run.step(&mut obj);
        }
        let ck = run.checkpoint();
        drop(run);
        assert!(
            ck.history.trials.iter().any(|t| t.value == f64::NEG_INFINITY),
            "seed must produce failed trials for this test to bite"
        );

        let proj = SpaceProjection::between(&space_a, &space_b);
        let out = proj.project_checkpoint(&ck, space_b.clone(), ProjectPolicy::Nearest);
        assert!(out.search.centroids.iter().all(|c| c.is_finite()));
        // The -inf trials survive as evidence...
        assert!(out
            .search
            .history
            .trials
            .iter()
            .any(|t| t.value == f64::NEG_INFINITY));
        // ...and the resumed run completes with valid proposals throughout.
        let mut obj_b = SyntheticObjective::with_space(space_b.clone(), std::time::Duration::ZERO);
        let mut resumed = searcher.start(space_b.clone(), 40, Some(&out.search)).unwrap();
        while !resumed.done() {
            resumed.step(&mut obj_b);
        }
        let hist = resumed.finish().0;
        assert_eq!(hist.len(), 40);
        for t in &hist.trials {
            assert!(space_b.validate(&t.config));
        }
    }

    /// Reports fabricated, strongly config-dependent per-eval timings
    /// through `eval_batch_timed` WITHOUT sleeping: the cost model sees a
    /// clean linear cost while the test stays instant and deterministic.
    /// `invert` flips the cost landscape (expensive <-> cheap), which a
    /// cost-ORDERED schedule would visibly react to.
    struct FakeCost {
        inner: SyntheticObjective,
        cap: usize,
        invert: bool,
    }

    impl FakeCost {
        fn new(dims: usize, choices: usize, cap: usize) -> FakeCost {
            FakeCost {
                inner: SyntheticObjective::new(dims, choices, std::time::Duration::ZERO),
                cap,
                invert: false,
            }
        }

        /// 5ms base + 2ms per unit of summed choice index — linear in the
        /// synthetic space's menu values (choice value == index there).
        fn fake_cost(c: &Config) -> f64 {
            0.005 + 0.002 * c.iter().sum::<usize>() as f64
        }
    }

    impl Objective for FakeCost {
        fn space(&self) -> &Space {
            self.inner.space()
        }
        fn eval(&mut self, c: &Config) -> f64 {
            self.inner.eval(c)
        }
        fn eval_batch_timed(&mut self, configs: &[Config]) -> (Vec<f64>, Vec<f64>) {
            let values = configs.iter().map(|c| self.inner.eval(c)).collect();
            let secs = configs
                .iter()
                .map(|c| {
                    let cost = FakeCost::fake_cost(c);
                    if self.invert {
                        0.100 - cost
                    } else {
                        cost
                    }
                })
                .collect();
            (values, secs)
        }
        fn parallelism(&self) -> usize {
            self.cap
        }
    }

    #[test]
    fn auto_rounds_are_longest_job_first_and_q_is_proactive() {
        // Acceptance (cost-model scheduler): under QPolicy::Auto the round
        // queue handed to the evaluator is ordered by predicted cost
        // DESCENDING, and q is sized from the fitted model — fabricated
        // multi-ms evals against a microsecond proposal path must saturate
        // the advertised capacity.
        let p = TpeParams { n_startup: 8, seed: 5, ..Default::default() };
        let mut searcher = BatchSearcher::tpe_auto(p);
        let mut obj = FakeCost::new(6, 4, 4);
        let h = searcher.run(&mut obj, 48);
        assert_eq!(h.len(), 48);

        // History order IS dispatch order; segment it by round and demand
        // non-increasing true cost inside every multi-config model round
        // after the first (the model is ready after 2*k = 6 observations,
        // i.e. within the 8-trial startup phase). Prediction order equals
        // true-cost order because the fabricated cost is exactly linear in
        // the model's features.
        let mut off = 0;
        let mut checked = 0;
        let mut model_rounds = 0;
        for r in &searcher.rounds {
            let seg = &h.trials[off..off + r.q];
            if !r.startup {
                model_rounds += 1;
                if model_rounds > 1 && r.q >= 2 {
                    for w in seg.windows(2) {
                        let (a, b) = (
                            FakeCost::fake_cost(&w[0].config),
                            FakeCost::fake_cost(&w[1].config),
                        );
                        assert!(
                            a >= b,
                            "round not longest-job-first: {:?} ({a:.3}s) before {:?} ({b:.3}s)",
                            w[0].config,
                            w[1].config
                        );
                    }
                    checked += 1;
                }
            }
            off += r.q;
        }
        assert!(checked >= 1, "no multi-config model rounds: {:?}", searcher.rounds);

        // Proactive q: the model predicts ~10ms evals, proposals cost
        // microseconds — model rounds must saturate capacity.
        let saturated =
            searcher.rounds.iter().filter(|r| !r.startup && r.q == 4).count();
        assert!(saturated >= 1, "q never saturated: {:?}", searcher.rounds);
    }

    #[test]
    fn cost_model_converges_through_a_batched_run() {
        // Acceptance (cost-model scheduler): the run's model, fit purely
        // from eval_batch_timed observations, converges to the synthetic
        // objective's true linear cost.
        let p = TpeParams { n_startup: 8, seed: 2, ..Default::default() };
        let searcher = BatchSearcher::tpe_auto(p);
        let mut obj = FakeCost::new(6, 4, 4);
        let mut run = searcher.start(obj.space().clone(), 40, None).unwrap();
        while !run.done() {
            run.step(&mut obj);
        }
        let model = run.cost_model();
        assert!(model.ready());
        for c in [vec![0, 0, 0, 0, 0, 0], vec![3, 3, 3, 3, 3, 3], vec![1, 0, 2, 3, 0, 1]] {
            let pred = model.predict(&c).unwrap();
            let truth = FakeCost::fake_cost(&c);
            assert!(
                (pred - truth).abs() < 1e-6 + 1e-4 * truth,
                "cost model diverged: pred {pred} vs truth {truth} for {c:?}"
            );
        }
    }

    #[test]
    fn fixed_q_rounds_are_never_reordered() {
        // The determinism contract: fixed-q histories are bit-identical
        // even when the two runs' observed eval COSTS disagree completely
        // (the second run inverts the cost landscape, which would permute
        // every round if the LJF reorder applied) — the reorder is
        // adaptive-policy-only.
        let p = TpeParams { n_startup: 6, seed: 8, ..Default::default() };
        let mut plain = FakeCost::new(5, 4, 4);
        let mut inverted = FakeCost::new(5, 4, 4);
        inverted.invert = true;
        let h1 = BatchSearcher::tpe(p, 4).run(&mut plain, 28);
        let h2 = BatchSearcher::tpe(p, 4).run(&mut inverted, 28);
        assert_eq!(h1.values(), h2.values());
        for (a, b) in h1.trials.iter().zip(&h2.trials) {
            assert_eq!(a.config, b.config);
        }
    }

    #[test]
    fn sequential_tpe_matches_batch_q1_semantics() {
        // q=1 uses the same incremental state as the sequential searcher;
        // histories differ only through the RNG stream, so both must find
        // comparable optima on an easy landscape.
        let p = TpeParams { n_startup: 10, seed: 4, ..Default::default() };
        let hb = BatchSearcher::tpe(p, 1).run(&mut Sep::new(4), 50);
        let hs = Tpe::new(p).run(&mut Sep::new(4), 50);
        assert_eq!(hb.len(), hs.len());
        // Optimum is 0; with 50 evals over a 256-config space both paths
        // must land near it.
        assert!(hb.best().unwrap().value >= -3.0, "batch best {}", hb.best().unwrap().value);
        assert!(hs.best().unwrap().value >= -3.0, "seq best {}", hs.best().unwrap().value);
    }
}
