//! OneCycleLR (Smith, 2018) — the schedule the paper trains final models
//! with (max lr 0.01). Linear warmup to `max_lr` over `pct_start` of the
//! run, then cosine annealing down to `max_lr / final_div`.

#[derive(Debug, Clone, Copy)]
pub struct OneCycle {
    pub max_lr: f64,
    pub total_steps: usize,
    pub pct_start: f64,
    pub div_factor: f64,
    pub final_div: f64,
}

impl OneCycle {
    pub fn new(max_lr: f64, total_steps: usize) -> OneCycle {
        OneCycle { max_lr, total_steps, pct_start: 0.3, div_factor: 25.0, final_div: 1e3 }
    }

    pub fn lr(&self, step: usize) -> f64 {
        let total = self.total_steps.max(1) as f64;
        let warm = (self.pct_start * total).max(1.0);
        let s = step as f64;
        if s < warm {
            let lo = self.max_lr / self.div_factor;
            lo + (self.max_lr - lo) * (s / warm)
        } else {
            let t = ((s - warm) / (total - warm).max(1.0)).clamp(0.0, 1.0);
            let lo = self.max_lr / self.final_div;
            lo + 0.5 * (self.max_lr - lo) * (1.0 + (std::f64::consts::PI * t).cos())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warms_up_peaks_and_decays() {
        let s = OneCycle::new(0.01, 100);
        assert!(s.lr(0) < s.lr(15));
        assert!(s.lr(15) < s.lr(29));
        let peak = s.lr(30);
        assert!((peak - 0.01).abs() < 1e-3);
        assert!(s.lr(99) < peak / 50.0);
    }

    #[test]
    fn monotone_decay_after_peak() {
        let s = OneCycle::new(0.01, 200);
        let mut prev = f64::INFINITY;
        for step in 60..200 {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }
}
