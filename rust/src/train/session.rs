//! ModelSession: one model's compiled programs + data, and the QAT
//! train/evaluate/hessian drivers on top of them.
//!
//! Input marshalling follows the flat program signatures documented in
//! meta.json (`python/compile/train.py`):
//!   train_step    : (*params, *m, *v, t, x, y, bits, widths, lr, wd)
//!   eval_batch    : (*params, x, y, bits, widths)
//!   hessian_trace : (*params, x, y, widths, seed)

use anyhow::Result;

use crate::data::synth::{ImageDataset, SynthSpec};
use crate::runtime::client::load_meta;
use crate::runtime::program::{
    lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, to_scalar_f32, to_vec_f32,
};
use crate::runtime::{ModelMeta, ParamInit, Program, Runtime};
use crate::train::schedule::OneCycle;
use crate::util::rng::Rng;

/// Optimizer state: parameter + Adam moment literals, ready for execution.
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    pub step: usize,
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub losses: Vec<f64>,
    pub final_loss: f64,
}

/// Host-side snapshot of parameters (for cloning into fine-tune runs).
#[derive(Clone)]
pub struct ParamSnapshot {
    pub tensors: Vec<Vec<f32>>,
}

impl ParamSnapshot {
    /// Content digest (FNV-1a over the raw f32 bits, tensor order included).
    /// A distributed search session's handshake compares the leader's and
    /// each worker's pretrained-snapshot digest: both sides pretrain
    /// deterministically from the same seed, so a mismatch means divergent
    /// starting points (different model, seed, or step count) and the
    /// session is rejected instead of silently searching skewed objectives.
    pub fn digest(&self) -> String {
        let mut h = crate::util::Fnv1a::new();
        for t in &self.tensors {
            // Length-prefix each tensor: without a boundary marker the
            // flattened byte streams of [[1,2],[3]] and [[1],[2,3]] would
            // collide, hiding a layer-structure mismatch.
            h.write_u64(t.len() as u64);
            for &x in t {
                h.write(&x.to_bits().to_le_bytes());
            }
        }
        h.hex()
    }
}

pub struct ModelSession {
    pub meta: ModelMeta,
    pub tag: String,
    train_prog: Program,
    eval_prog: Program,
    hess_prog: Program,
    pub train_data: ImageDataset,
    pub val_data: ImageDataset,
    /// Weight decay used in every run.
    pub weight_decay: f32,
}

impl ModelSession {
    /// Open artifacts for `tag` ("resnet20-cifar10") and generate its proxy
    /// datasets (sizes tuned for single-core proxy training).
    pub fn open(rt: &Runtime, tag: &str, train_n: usize, val_n: usize) -> Result<ModelSession> {
        let meta = load_meta(tag)?;
        let dir = Runtime::model_dir(tag)?;
        let train_prog = rt.load_program(&dir.join("train_step.hlo.txt"))?;
        let eval_prog = rt.load_program(&dir.join("eval_batch.hlo.txt"))?;
        let hess_prog = rt.load_program(&dir.join("hessian_trace.hlo.txt"))?;
        let spec = SynthSpec::by_name(&meta.dataset)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {}", meta.dataset))?;
        anyhow::ensure!(
            spec.classes == meta.num_classes,
            "dataset classes {} != model classes {}",
            spec.classes,
            meta.num_classes
        );
        let train_data = ImageDataset::generate(spec, train_n, 1);
        let val_data = ImageDataset::generate(spec, val_n, 2);
        Ok(ModelSession {
            meta,
            tag: tag.to_string(),
            train_prog,
            eval_prog,
            hess_prog,
            train_data,
            val_data,
            weight_decay: 1e-4,
        })
    }

    // -- parameters ---------------------------------------------------------

    /// He / ones / zeros initialization per meta.json.
    pub fn init_snapshot(&self, seed: u64) -> ParamSnapshot {
        let mut rng = Rng::new(seed ^ 0x1A17);
        let tensors = self
            .meta
            .params
            .iter()
            .map(|p| {
                let n = p.num_elements();
                match p.init {
                    ParamInit::He => {
                        let std = (2.0 / p.fan_in.max(1) as f64).sqrt();
                        (0..n).map(|_| (rng.gauss() * std) as f32).collect()
                    }
                    ParamInit::Ones => vec![1f32; n],
                    ParamInit::Zeros => vec![0f32; n],
                }
            })
            .collect();
        ParamSnapshot { tensors }
    }

    fn param_dims(&self, i: usize) -> Vec<i64> {
        self.meta.params[i].shape.iter().map(|&d| d as i64).collect()
    }

    /// Upload a snapshot as a fresh TrainState (zero moments).
    pub fn state_from_snapshot(&self, snap: &ParamSnapshot) -> Result<TrainState> {
        let mut params = Vec::with_capacity(snap.tensors.len());
        let mut m = Vec::with_capacity(snap.tensors.len());
        let mut v = Vec::with_capacity(snap.tensors.len());
        for (i, t) in snap.tensors.iter().enumerate() {
            let dims = self.param_dims(i);
            params.push(lit_f32(t, &dims)?);
            m.push(lit_f32(&vec![0f32; t.len()], &dims)?);
            v.push(lit_f32(&vec![0f32; t.len()], &dims)?);
        }
        Ok(TrainState { params, m, v, step: 0 })
    }

    /// Download the parameters of a state back to the host.
    pub fn snapshot_of(&self, state: &TrainState) -> Result<ParamSnapshot> {
        let tensors = state
            .params
            .iter()
            .map(to_vec_f32)
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamSnapshot { tensors })
    }

    // -- training -----------------------------------------------------------

    fn batch_literals(&self, data: &ImageDataset, b: usize) -> Result<(xla::Literal, xla::Literal)> {
        let bs = self.meta.batch;
        let hw = self.meta.image_hw;
        let px = data.pixels_per_image();
        let mut x = vec![0f32; bs * px];
        let mut y = vec![0i32; bs];
        data.fill_batch(b, bs, &mut x, &mut y);
        Ok((
            lit_f32(&x, &[bs as i64, hw as i64, hw as i64, 3])?,
            lit_i32(&y, &[bs as i64])?,
        ))
    }

    /// Run `steps` QAT steps on `state` under the given (bits, widths)
    /// vectors with a OneCycle schedule peaking at `max_lr`.
    pub fn train(
        &self,
        state: &mut TrainState,
        bits: &[f32],
        widths: &[f32],
        steps: usize,
        max_lr: f64,
    ) -> Result<TrainOutcome> {
        let n = self.meta.params.len();
        let nl = self.meta.num_layers as i64;
        let bits_l = lit_f32(bits, &[nl])?;
        let widths_l = lit_f32(widths, &[nl])?;
        let wd = lit_scalar_f32(self.weight_decay);
        let sched = OneCycle::new(max_lr, steps);
        let mut losses = Vec::with_capacity(steps);

        for s in 0..steps {
            let (x, y) = self.batch_literals(&self.train_data, state.step + s)?;
            let t = lit_scalar_f32((state.step + s) as f32);
            let lr = lit_scalar_f32(sched.lr(s) as f32);
            let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * n + 7);
            args.extend(state.params.iter());
            args.extend(state.m.iter());
            args.extend(state.v.iter());
            args.push(&t);
            args.push(&x);
            args.push(&y);
            args.push(&bits_l);
            args.push(&widths_l);
            args.push(&lr);
            args.push(&wd);
            let mut out = self.train_prog.run(&args)?;
            anyhow::ensure!(out.len() == 3 * n + 1, "train_step arity {}", out.len());
            let loss = to_scalar_f32(&out[3 * n])? as f64;
            losses.push(loss);
            // Rotate state: outputs become next inputs (device literals are
            // moved, never copied through host).
            let vv: Vec<xla::Literal> = out.drain(2 * n..3 * n).collect();
            let mm: Vec<xla::Literal> = out.drain(n..2 * n).collect();
            let pp: Vec<xla::Literal> = out.drain(0..n).collect();
            state.params = pp;
            state.m = mm;
            state.v = vv;
        }
        state.step += steps;
        let final_loss = losses.last().copied().unwrap_or(f64::NAN);
        Ok(TrainOutcome { losses, final_loss })
    }

    // -- evaluation ----------------------------------------------------------

    /// Validation accuracy over `n_batches` batches (wraps the val set).
    pub fn evaluate(
        &self,
        state: &TrainState,
        bits: &[f32],
        widths: &[f32],
        n_batches: usize,
    ) -> Result<f64> {
        let nl = self.meta.num_layers as i64;
        let bits_l = lit_f32(bits, &[nl])?;
        let widths_l = lit_f32(widths, &[nl])?;
        let mut correct = 0.0;
        let mut total = 0.0;
        for b in 0..n_batches {
            let (x, y) = self.batch_literals(&self.val_data, b)?;
            let mut args: Vec<&xla::Literal> =
                Vec::with_capacity(self.meta.params.len() + 4);
            args.extend(state.params.iter());
            args.push(&x);
            args.push(&y);
            args.push(&bits_l);
            args.push(&widths_l);
            let out = self.eval_prog.run(&args)?;
            correct += to_scalar_f32(&out[0])? as f64;
            total += self.meta.batch as f64;
        }
        Ok(correct / total)
    }

    // -- sensitivity ----------------------------------------------------------

    /// Hutchinson Hessian-trace estimates per quantized layer, averaged over
    /// `n_samples` (seed, batch) draws. Returns RAW vHv sums; the pruner
    /// normalizes by parameter counts (§III-A).
    pub fn hessian_traces(
        &self,
        state: &TrainState,
        widths: &[f32],
        n_samples: usize,
    ) -> Result<Vec<f64>> {
        let nl = self.meta.num_layers;
        let widths_l = lit_f32(widths, &[nl as i64])?;
        let mut acc = vec![0f64; nl];
        for s in 0..n_samples {
            let (x, y) = self.batch_literals(&self.train_data, s)?;
            let seed = lit_scalar_i32(s as i32 + 1);
            let mut args: Vec<&xla::Literal> =
                Vec::with_capacity(self.meta.params.len() + 4);
            args.extend(state.params.iter());
            args.push(&x);
            args.push(&y);
            args.push(&widths_l);
            args.push(&seed);
            let out = self.hess_prog.run(&args)?;
            let est = to_vec_f32(&out[0])?;
            anyhow::ensure!(est.len() == nl, "hessian arity {}", est.len());
            for (a, e) in acc.iter_mut().zip(est) {
                *a += e as f64;
            }
        }
        for a in acc.iter_mut() {
            *a /= n_samples.max(1) as f64;
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_digest_is_content_sensitive() {
        let a = ParamSnapshot { tensors: vec![vec![1.0, 2.0], vec![-0.5]] };
        let b = ParamSnapshot { tensors: vec![vec![1.0, 2.0], vec![-0.5]] };
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.digest().len(), 16);
        // One flipped bit anywhere changes the digest.
        let c = ParamSnapshot { tensors: vec![vec![1.0, 2.0], vec![-0.5000001]] };
        assert_ne!(a.digest(), c.digest());
        // Tensor boundaries matter: [[1,2],[]] != [[1],[2]].
        let d = ParamSnapshot { tensors: vec![vec![1.0], vec![2.0, -0.5]] };
        assert_ne!(a.digest(), d.digest());
    }
}
