//! Parameter checkpointing: save/load `ParamSnapshot`s in a simple versioned
//! binary format (`.sqck`), so pretrained models are reused across CLI runs
//! instead of re-pretraining per invocation.
//!
//! Layout (little-endian):
//!   magic "SQCK" | u32 version | u32 n_tensors |
//!   per tensor: u32 name_len | name bytes | u32 elem_count | f32 data...
//! A trailing u64 XOR checksum over the data words guards truncation.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::session::ParamSnapshot;

const MAGIC: &[u8; 4] = b"SQCK";
const VERSION: u32 = 1;

pub fn save(path: &Path, names: &[String], snap: &ParamSnapshot) -> Result<()> {
    anyhow::ensure!(names.len() == snap.tensors.len(), "names/tensors mismatch");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(snap.tensors.len() as u32).to_le_bytes())?;
    let mut checksum: u64 = 0;
    for (name, t) in names.iter().zip(&snap.tensors) {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.len() as u32).to_le_bytes())?;
        for &v in t {
            let b = v.to_bits();
            checksum ^= (b as u64).rotate_left((t.len() % 63) as u32);
            f.write_all(&b.to_le_bytes())?;
        }
    }
    f.write_all(&checksum.to_le_bytes())?;
    Ok(())
}

pub fn load(path: &Path) -> Result<(Vec<String>, ParamSnapshot)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open checkpoint {}", path.display()))?;
    let mut buf4 = [0u8; 4];
    f.read_exact(&mut buf4)?;
    anyhow::ensure!(&buf4 == MAGIC, "not a sammpq checkpoint");
    f.read_exact(&mut buf4)?;
    let version = u32::from_le_bytes(buf4);
    anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
    f.read_exact(&mut buf4)?;
    let n = u32::from_le_bytes(buf4) as usize;
    anyhow::ensure!(n < 100_000, "implausible tensor count {n}");

    let mut names = Vec::with_capacity(n);
    let mut tensors = Vec::with_capacity(n);
    let mut checksum: u64 = 0;
    for _ in 0..n {
        f.read_exact(&mut buf4)?;
        let name_len = u32::from_le_bytes(buf4) as usize;
        anyhow::ensure!(name_len < 4096, "implausible name length");
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        names.push(String::from_utf8(name).context("name utf8")?);
        f.read_exact(&mut buf4)?;
        let count = u32::from_le_bytes(buf4) as usize;
        let mut bytes = vec![0u8; count * 4];
        f.read_exact(&mut bytes)?;
        let mut t = Vec::with_capacity(count);
        for c in bytes.chunks_exact(4) {
            let b = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            checksum ^= (b as u64).rotate_left((count % 63) as u32);
            t.push(f32::from_bits(b));
        }
        tensors.push(t);
    }
    let mut buf8 = [0u8; 8];
    f.read_exact(&mut buf8).context("missing checksum (truncated?)")?;
    anyhow::ensure!(
        u64::from_le_bytes(buf8) == checksum,
        "checkpoint checksum mismatch (corrupted)"
    );
    Ok((names, ParamSnapshot { tensors }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sammpq_ck_{name}.sqck"))
    }

    fn snap() -> (Vec<String>, ParamSnapshot) {
        (
            vec!["a.w".into(), "b.bias".into()],
            ParamSnapshot {
                tensors: vec![vec![1.0, -2.5, 3.25], vec![0.0, f32::MIN_POSITIVE]],
            },
        )
    }

    #[test]
    fn roundtrip() {
        let p = tmp("rt");
        let (names, s) = snap();
        save(&p, &names, &s).unwrap();
        let (n2, s2) = load(&p).unwrap();
        assert_eq!(names, n2);
        assert_eq!(s.tensors, s2.tensors);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn detects_truncation() {
        let p = tmp("trunc");
        let (names, s) = snap();
        save(&p, &names, &s).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 6]).unwrap();
        assert!(load(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn detects_corruption() {
        let p = tmp("corrupt");
        let (names, s) = snap();
        save(&p, &names, &s).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn rejects_wrong_magic() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOPExxxxxxxxxxxx").unwrap();
        assert!(load(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
