//! Training driver: QAT proxy-training and evaluation of configurations by
//! executing the AOT-compiled train_step / eval_batch / hessian_trace
//! programs. The OneCycleLR schedule the paper uses lives here too — the lr
//! is a runtime input of train_step, so the schedule is pure Rust.

pub mod checkpoint;
pub mod schedule;
pub mod session;

pub use schedule::OneCycle;
pub use session::{ModelSession, TrainOutcome, TrainState};
