//! HiKonv-extended operand/operation packing (§III-C, Fig. 2).
//!
//! A DSP48E2 performs a 27x18-bit multiply + 48-bit accumulate each cycle.
//! By packing multiple low-bit operands into each multiplier input (with
//! guard bits so partial products don't collide), one DSP performs several
//! low-bit MACs per cycle. The paper extends HiKonv's 1-D packing to 2-D
//! convolution and reports:
//!
//!   8- or 6-bit operands -> 2 multiplications / DSP / cycle
//!   4- or 3-bit operands -> 6 multiplications + 2 additions
//!   2-bit operands       -> 15 multiplications + 8 additions
//!
//! FiP16 (the baseline) gets 1 multiplication per DSP per cycle.

/// (bits, packed multiplications per DSP per cycle, packed additions).
pub const PACK_TABLE: [(u32, u32, u32); 6] = [
    (16, 1, 0),
    (8, 2, 0),
    (6, 2, 0),
    (4, 6, 2),
    (3, 6, 2),
    (2, 15, 8),
];

/// Packed multiplications per DSP per cycle for a given operand bit-width.
/// Unlisted widths round UP to the next supported width (conservative).
pub fn macs_per_dsp(bits: u32) -> u32 {
    if bits >= 9 {
        return 1; // 9..16+ : no packing on a 27x18 DSP for two-operand MACs
    }
    let mut best = 1;
    for &(b, mults, _) in PACK_TABLE.iter() {
        if bits <= b {
            best = mults;
        }
    }
    best
}

/// Bonus additions folded into the same DSP pass (tree-adder savings).
pub fn adds_per_dsp(bits: u32) -> u32 {
    if bits >= 9 {
        return 0;
    }
    let mut best = 0;
    for &(b, _, adds) in PACK_TABLE.iter() {
        if bits <= b {
            best = adds;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table_values() {
        assert_eq!(macs_per_dsp(16), 1);
        assert_eq!(macs_per_dsp(8), 2);
        assert_eq!(macs_per_dsp(6), 2);
        assert_eq!(macs_per_dsp(4), 6);
        assert_eq!(macs_per_dsp(3), 6);
        assert_eq!(macs_per_dsp(2), 15);
        assert_eq!(adds_per_dsp(2), 8);
        assert_eq!(adds_per_dsp(4), 2);
        assert_eq!(adds_per_dsp(8), 0);
    }

    #[test]
    fn monotone_nonincreasing_in_bits() {
        let mut prev = u32::MAX;
        for bits in [2, 3, 4, 6, 8, 16] {
            let m = macs_per_dsp(bits);
            assert!(m <= prev, "packing should not grow with bits");
            prev = m;
        }
    }

    #[test]
    fn intermediate_widths_round_up() {
        assert_eq!(macs_per_dsp(5), 2); // treated as 6-bit
        assert_eq!(macs_per_dsp(7), 2); // treated as 8-bit
        assert_eq!(macs_per_dsp(12), 1);
    }
}
