//! Network shape description under a concrete (bits, widths) configuration.
//!
//! The coordinator builds a `NetShape` from the artifact's layer metadata
//! (meta.json) by resolving width ties to ACTIVE channel counts; every
//! hardware metric (size, latency, energy, speedup) is a pure function of it.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    DwConv,
    PwConv,
    Fc,
}

impl LayerKind {
    pub fn parse(s: &str) -> Option<LayerKind> {
        match s {
            "conv" => Some(LayerKind::Conv),
            "dwconv" => Some(LayerKind::DwConv),
            "pwconv" => Some(LayerKind::PwConv),
            "fc" => Some(LayerKind::Fc),
            _ => None,
        }
    }
}

/// One quantized layer with RESOLVED active channel counts and bit-width.
#[derive(Debug, Clone)]
pub struct LayerShape {
    pub name: String,
    pub kind: LayerKind,
    pub ksize: usize,
    pub cin: usize,
    pub cout: usize,
    pub out_h: usize,
    pub out_w: usize,
    pub bits: u32,
}

impl LayerShape {
    /// Multiply-accumulates for one input image.
    pub fn macs(&self) -> u64 {
        let px = (self.out_h * self.out_w) as u64;
        match self.kind {
            LayerKind::Conv => {
                px * self.cout as u64 * (self.ksize * self.ksize * self.cin) as u64
            }
            LayerKind::DwConv => px * self.cout as u64 * (self.ksize * self.ksize) as u64,
            LayerKind::PwConv => px * self.cout as u64 * self.cin as u64,
            LayerKind::Fc => (self.cin * self.cout) as u64,
        }
    }

    /// Number of weight parameters.
    pub fn weights(&self) -> u64 {
        match self.kind {
            LayerKind::Conv | LayerKind::PwConv => {
                (self.ksize * self.ksize * self.cin * self.cout) as u64
            }
            LayerKind::DwConv => (self.ksize * self.ksize * self.cout) as u64,
            LayerKind::Fc => (self.cin * self.cout) as u64,
        }
    }

    /// Weight storage in bits under this layer's quantization.
    pub fn weight_bits(&self) -> u64 {
        self.weights() * self.bits as u64
    }

    /// Input-patch length N' of the systolic dataflow (§III-C): the number
    /// of entries in the input feature patch reduced per output value.
    pub fn patch_len(&self) -> usize {
        match self.kind {
            LayerKind::Conv => self.ksize * self.ksize * self.cin,
            LayerKind::DwConv => self.ksize * self.ksize,
            LayerKind::PwConv => self.cin,
            LayerKind::Fc => self.cin,
        }
    }

    /// Output values ("pixels" x channels handled by the M dimension).
    pub fn out_pixels(&self) -> usize {
        match self.kind {
            LayerKind::Fc => 1,
            _ => self.out_h * self.out_w,
        }
    }
}

/// A whole network under one configuration.
#[derive(Debug, Clone)]
pub struct NetShape {
    pub layers: Vec<LayerShape>,
}

impl NetShape {
    /// Model size in megabytes (weights only, as the paper reports).
    pub fn model_size_mb(&self) -> f64 {
        let bits: u64 = self.layers.iter().map(|l| l.weight_bits()).sum();
        bits as f64 / 8.0 / 1e6
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(cin: usize, cout: usize, hw: usize, k: usize, bits: u32) -> LayerShape {
        LayerShape {
            name: "t".into(),
            kind: LayerKind::Conv,
            ksize: k,
            cin,
            cout,
            out_h: hw,
            out_w: hw,
            bits,
        }
    }

    #[test]
    fn conv_macs_and_weights() {
        let l = conv(16, 32, 8, 3, 4);
        assert_eq!(l.weights(), 3 * 3 * 16 * 32);
        assert_eq!(l.macs(), 64 * 32 * (9 * 16));
        assert_eq!(l.weight_bits(), l.weights() * 4);
        assert_eq!(l.patch_len(), 144);
    }

    #[test]
    fn dw_vs_pw() {
        let dw = LayerShape { kind: LayerKind::DwConv, ..conv(32, 32, 8, 3, 8) };
        assert_eq!(dw.weights(), 9 * 32);
        assert_eq!(dw.macs(), 64 * 32 * 9);
        let pw = LayerShape { kind: LayerKind::PwConv, ksize: 1, ..conv(32, 64, 8, 1, 8) };
        assert_eq!(pw.weights(), 32 * 64);
        assert_eq!(pw.macs(), 64 * 64 * 32);
    }

    #[test]
    fn model_size_linear_in_bits(){
        let n8 = NetShape { layers: vec![conv(16, 16, 8, 3, 8)] };
        let n4 = NetShape { layers: vec![conv(16, 16, 8, 3, 4)] };
        let n2 = NetShape { layers: vec![conv(16, 16, 8, 3, 2)] };
        assert!((n8.model_size_mb() / n4.model_size_mb() - 2.0).abs() < 1e-9);
        assert!((n8.model_size_mb() / n2.model_size_mb() - 4.0).abs() < 1e-9);
    }
}
