//! Hardware-aware performance models (§III-C of the paper).
//!
//! The paper's target is a Xilinx FPGA accelerator: an M x N systolic array
//! of DSP+BRAM processing elements with a DRAM/URAM/BRAM memory hierarchy,
//! where HiKonv-style operand packing executes multiple low-bit MACs per DSP
//! per cycle. The paper derives model size and speedup *analytically* from
//! this design ("the overall model size reduction and speedup can be easily
//! calculated"); this module implements that analytic model — plus a
//! cycle-level simulator (`sim`) that validates it.

pub mod packing;
pub mod model;
pub mod latency;
pub mod energy;
pub mod sim;

pub use latency::{baseline_latency_cycles, latency_cycles, LayerLatency};
pub use model::{LayerKind, LayerShape, NetShape};
pub use packing::{macs_per_dsp, PACK_TABLE};

/// Accelerator configuration (defaults follow the paper's description:
/// 2-D systolic array of DSP48E2-based PEs; each DSP does one 27x18 multiply
/// + 48-bit accumulate per cycle at FiP16, more via packing at low bits).
#[derive(Debug, Clone, Copy)]
pub struct HwConfig {
    /// Systolic array rows (output channels processed in parallel).
    pub m: usize,
    /// Systolic array columns (input-patch entries processed in parallel).
    pub n: usize,
    /// Clock in MHz (DSP48E2 conservatively at 300 MHz).
    pub clock_mhz: f64,
    /// DRAM bandwidth in bytes/cycle (e.g. 16 B/cyc ~ 4.8 GB/s @300MHz).
    pub dram_bytes_per_cycle: f64,
    /// Fraction of DRAM traffic overlapped with compute (double buffering).
    pub dram_overlap: f64,
    /// Energy per DSP MAC-cycle in pJ.
    pub dsp_pj_per_cycle: f64,
    /// Energy per BRAM access (one operand line) in pJ.
    pub bram_pj_per_access: f64,
    /// Energy per DRAM byte in pJ.
    pub dram_pj_per_byte: f64,
}

impl Default for HwConfig {
    fn default() -> Self {
        HwConfig {
            m: 16,
            n: 16,
            clock_mhz: 300.0,
            dram_bytes_per_cycle: 16.0,
            dram_overlap: 0.8,
            dsp_pj_per_cycle: 4.5,
            bram_pj_per_access: 2.5,
            dram_pj_per_byte: 80.0,
        }
    }
}

impl HwConfig {
    pub fn cycles_to_ms(&self, cycles: f64) -> f64 {
        cycles / (self.clock_mhz * 1e3)
    }

    /// Wire encoding for the search-session handshake: workers must compute
    /// size/latency with the LEADER's accelerator model, or the J values they
    /// return silently disagree with the report the leader assembles.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        obj(vec![
            ("m", Json::Num(self.m as f64)),
            ("n", Json::Num(self.n as f64)),
            ("clock_mhz", Json::Num(self.clock_mhz)),
            ("dram_bytes_per_cycle", Json::Num(self.dram_bytes_per_cycle)),
            ("dram_overlap", Json::Num(self.dram_overlap)),
            ("dsp_pj_per_cycle", Json::Num(self.dsp_pj_per_cycle)),
            ("bram_pj_per_access", Json::Num(self.bram_pj_per_access)),
            ("dram_pj_per_byte", Json::Num(self.dram_pj_per_byte)),
        ])
    }

    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<HwConfig> {
        use anyhow::Context;
        let f = |k: &str| -> anyhow::Result<f64> {
            j.req(k)?.as_f64().with_context(|| format!("hw field '{k}' must be numeric"))
        };
        Ok(HwConfig {
            m: f("m")? as usize,
            n: f("n")? as usize,
            clock_mhz: f("clock_mhz")?,
            dram_bytes_per_cycle: f("dram_bytes_per_cycle")?,
            dram_overlap: f("dram_overlap")?,
            dsp_pj_per_cycle: f("dsp_pj_per_cycle")?,
            bram_pj_per_access: f("bram_pj_per_access")?,
            dram_pj_per_byte: f("dram_pj_per_byte")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hw_config_serde_roundtrip_is_byte_identical() {
        let hw = HwConfig { m: 32, dram_overlap: 0.75, ..Default::default() };
        let text = hw.to_json().to_string_pretty();
        let back =
            HwConfig::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string_pretty(), text);
        assert_eq!(back.m, 32);
        assert_eq!(back.dram_overlap, 0.75);
    }
}
