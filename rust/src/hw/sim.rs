//! Cycle-level simulator of the §III-C accelerator.
//!
//! Where `latency.rs` uses the paper's closed-form count, this simulator
//! executes the dataflow schedule: per-layer DRAM weight prefetch (double
//! buffered against the previous layer's compute), per-pass weight-segment
//! staging into PE BRAMs, pixel streaming through the M-deep PE pipeline,
//! and the tree-adder drain. It exists to validate the analytic model (an
//! integration test asserts agreement within tolerance) and to expose
//! utilization/bottleneck detail the closed form hides.

use super::model::NetShape;
use super::packing::macs_per_dsp;
use super::HwConfig;

#[derive(Debug, Clone, Default)]
pub struct LayerSim {
    pub name: String,
    pub start_cycle: u64,
    pub end_cycle: u64,
    pub prefetch_wait: u64,
    pub passes: u64,
}

#[derive(Debug, Clone, Default)]
pub struct SimResult {
    pub total_cycles: u64,
    pub layers: Vec<LayerSim>,
    /// MAC utilization: useful MACs / (cycles * array MAC capacity at each
    /// layer's packing factor).
    pub utilization: f64,
}

/// Simulate one image through the network.
pub fn simulate(hw: &HwConfig, net: &NetShape) -> SimResult {
    let mut clock: u64 = 0; // global cycle counter
    let mut layers = Vec::with_capacity(net.layers.len());
    let mut useful_capacity = 0f64;

    // Prefetch of layer 0 cannot overlap anything.
    let first_bytes = net.layers[0].weight_bits() as f64 / 8.0;
    let first_cycles = (first_bytes / hw.dram_bytes_per_cycle).ceil() as u64;
    // DRAM channel availability / first-layer weights arrival.
    let mut prefetch_free_at: u64 = first_cycles;
    let mut prefetch_done_at: u64 = first_cycles;

    for (i, l) in net.layers.iter().enumerate() {
        // Wait for this layer's weights.
        let wait = prefetch_done_at.saturating_sub(clock);
        clock = clock.max(prefetch_done_at);
        let start = clock;

        // Kick off the NEXT layer's prefetch now (double buffering): it
        // shares the DRAM channel, serialized on prefetch_free_at.
        if i + 1 < net.layers.len() {
            let bytes = net.layers[i + 1].weight_bits() as f64 / 8.0;
            let cycles = (bytes / hw.dram_bytes_per_cycle).ceil() as u64;
            let begin = prefetch_free_at.max(clock);
            prefetch_free_at = begin + cycles;
            prefetch_done_at = begin + cycles;
        }

        // Compute: march every (m_pass, n_pass) tile.
        let pack = macs_per_dsp(l.bits) as u64;
        let n_eff = (hw.n as u64 * pack).max(1);
        let m_passes = (l.cout as u64).div_ceil(hw.m as u64);
        let n_passes = (l.patch_len() as u64).div_ceil(n_eff);
        let p = l.out_pixels() as u64;
        let tree_depth = (hw.n as f64).log2().ceil() as u64 + 1;
        let mut passes = 0;
        for _mp in 0..m_passes {
            for _np in 0..n_passes {
                // Stage this pass's weight segment from URAM into PE BRAMs
                // (one row per cycle), then stream P pixels through the
                // M-deep pipeline and drain the tree adder.
                let staging = hw.m as u64;
                let stream = p; // one pixel set enters per cycle
                let fill_drain = hw.m as u64 + tree_depth;
                clock += staging + stream + fill_drain;
                passes += 1;
            }
        }
        useful_capacity += (passes * (p + hw.m as u64 + hw.n as u64)) as f64
            * (hw.m * hw.n) as f64
            * pack as f64;

        layers.push(LayerSim {
            name: l.name.clone(),
            start_cycle: start,
            end_cycle: clock,
            prefetch_wait: wait,
            passes,
        });
    }

    let total_macs: u64 = net.total_macs();
    let utilization = if useful_capacity > 0.0 {
        total_macs as f64 / useful_capacity
    } else {
        0.0
    };
    SimResult { total_cycles: clock, layers, utilization }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::latency::latency_cycles;
    use crate::hw::model::{LayerKind, LayerShape};

    fn small_net(bits: u32) -> NetShape {
        let conv = |name: &str, cin, cout, hw_px, k, kind| LayerShape {
            name: name.into(),
            kind,
            ksize: k,
            cin,
            cout,
            out_h: hw_px,
            out_w: hw_px,
            bits,
        };
        NetShape {
            layers: vec![
                conv("stem", 3, 16, 16, 3, LayerKind::Conv),
                conv("c1", 16, 16, 16, 3, LayerKind::Conv),
                conv("c2", 16, 32, 8, 3, LayerKind::Conv),
                conv("pw", 32, 64, 8, 1, LayerKind::PwConv),
                conv("fc", 64, 10, 1, 1, LayerKind::Fc),
            ],
        }
    }

    #[test]
    fn sim_matches_analytic_within_tolerance() {
        let hw = HwConfig::default();
        for bits in [16, 8, 4, 2] {
            let net = small_net(bits);
            let sim = simulate(&hw, &net).total_cycles as f64;
            let analytic = latency_cycles(&hw, &net);
            let ratio = sim / analytic;
            assert!(
                (0.6..1.6).contains(&ratio),
                "bits={bits}: sim {sim} vs analytic {analytic} (ratio {ratio})"
            );
        }
    }

    #[test]
    fn sim_preserves_packing_speedup_ordering() {
        let hw = HwConfig::default();
        let c16 = simulate(&hw, &small_net(16)).total_cycles;
        let c8 = simulate(&hw, &small_net(8)).total_cycles;
        let c4 = simulate(&hw, &small_net(4)).total_cycles;
        let c2 = simulate(&hw, &small_net(2)).total_cycles;
        assert!(c16 > c8 && c8 > c4 && c4 > c2, "{c16} {c8} {c4} {c2}");
    }

    #[test]
    fn layers_execute_in_order() {
        let hw = HwConfig::default();
        let r = simulate(&hw, &small_net(8));
        for w in r.layers.windows(2) {
            assert!(w[0].end_cycle <= w[1].start_cycle);
        }
        assert_eq!(r.total_cycles, r.layers.last().unwrap().end_cycle);
    }

    #[test]
    fn utilization_in_unit_range() {
        let r = simulate(&HwConfig::default(), &small_net(4));
        assert!(r.utilization > 0.0 && r.utilization <= 1.0, "{}", r.utilization);
    }
}
