//! Analytic latency model of the systolic-array dataflow (§III-C).
//!
//! Dataflow per layer: sets of N input-patch entries are loaded into the
//! first PE row and march down the M rows while being multiplied with the
//! per-PE BRAM-resident weights; partial products accumulate in-PE and drain
//! through one tree adder ("processing unit") per row. Covering the full
//! patch takes ceil(N'/N) array invocations, covering all output channels
//! takes ceil(M'/M) invocations, and HiKonv packing divides the patch
//! coverage by `macs_per_dsp(bits)`.
//!
//!   passes(l)   = ceil(M'/M) * ceil(N'/(N * pack(b)))
//!   cycles(l)   = passes * (P + M + N)            ; P pixels streamed,
//!                                                    M+N pipeline fill/drain
//!   stall(l)    = (1 - overlap) * weight_bytes / dram_bw
//!
//! Latency is per-image (batch 1), the paper's deployment scenario.

use super::model::{LayerShape, NetShape};
use super::packing::macs_per_dsp;
use super::HwConfig;

#[derive(Debug, Clone)]
pub struct LayerLatency {
    pub name: String,
    pub compute_cycles: f64,
    pub dram_stall_cycles: f64,
    pub passes: u64,
}

impl LayerLatency {
    pub fn total(&self) -> f64 {
        self.compute_cycles + self.dram_stall_cycles
    }
}

pub fn layer_latency(hw: &HwConfig, l: &LayerShape) -> LayerLatency {
    let pack = macs_per_dsp(l.bits) as f64;
    let n_eff = (hw.n as f64 * pack).max(1.0);
    let m_passes = (l.cout as f64 / hw.m as f64).ceil();
    let n_passes = (l.patch_len() as f64 / n_eff).ceil();
    let passes = m_passes * n_passes;
    let p = l.out_pixels() as f64;
    let compute = passes * (p + (hw.m + hw.n) as f64);

    let weight_bytes = l.weight_bits() as f64 / 8.0;
    let dram_cycles = weight_bytes / hw.dram_bytes_per_cycle;
    let stall = (1.0 - hw.dram_overlap) * dram_cycles;

    LayerLatency {
        name: l.name.clone(),
        compute_cycles: compute,
        dram_stall_cycles: stall,
        passes: passes as u64,
    }
}

/// End-to-end single-image latency in cycles.
pub fn latency_cycles(hw: &HwConfig, net: &NetShape) -> f64 {
    net.layers.iter().map(|l| layer_latency(hw, l).total()).sum()
}

/// Per-layer breakdown.
pub fn latency_breakdown(hw: &HwConfig, net: &NetShape) -> Vec<LayerLatency> {
    net.layers.iter().map(|l| layer_latency(hw, l)).collect()
}

/// FiP16 baseline: same network, all layers at 16 bits (packing = 1).
pub fn baseline_latency_cycles(hw: &HwConfig, net: &NetShape) -> f64 {
    let base = NetShape {
        layers: net
            .layers
            .iter()
            .map(|l| LayerShape { bits: 16, ..l.clone() })
            .collect(),
    };
    latency_cycles(hw, &base)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::model::LayerKind;

    fn conv(cin: usize, cout: usize, hw_px: usize, bits: u32) -> LayerShape {
        LayerShape {
            name: "t".into(),
            kind: LayerKind::Conv,
            ksize: 3,
            cin,
            cout,
            out_h: hw_px,
            out_w: hw_px,
            bits,
        }
    }

    #[test]
    fn packing_speeds_up() {
        let hw = HwConfig::default();
        let net16 = NetShape { layers: vec![conv(64, 64, 16, 16)] };
        let net4 = NetShape { layers: vec![conv(64, 64, 16, 4)] };
        let net2 = NetShape { layers: vec![conv(64, 64, 16, 2)] };
        let l16 = latency_cycles(&hw, &net16);
        let l4 = latency_cycles(&hw, &net4);
        let l2 = latency_cycles(&hw, &net2);
        assert!(l4 < l16 / 3.0, "4-bit {l4} vs 16-bit {l16}");
        assert!(l2 < l4, "2-bit {l2} vs 4-bit {l4}");
        // Speedup bounded by the packing factor.
        assert!(l16 / l2 <= 15.0 + 1e-9);
    }

    #[test]
    fn baseline_equals_16bit() {
        let hw = HwConfig::default();
        let net = NetShape { layers: vec![conv(32, 32, 8, 3)] };
        let base = baseline_latency_cycles(&hw, &net);
        let explicit = latency_cycles(&hw, &NetShape { layers: vec![conv(32, 32, 8, 16)] });
        assert_eq!(base, explicit);
    }

    #[test]
    fn wider_layers_cost_more() {
        let hw = HwConfig::default();
        let narrow = latency_cycles(&hw, &NetShape { layers: vec![conv(32, 24, 8, 4)] });
        let wide = latency_cycles(&hw, &NetShape { layers: vec![conv(32, 40, 8, 4)] });
        assert!(wide > narrow);
    }

    #[test]
    fn stall_scales_with_bits() {
        let hw = HwConfig { dram_overlap: 0.0, ..Default::default() };
        let l8 = layer_latency(&hw, &conv(16, 16, 8, 8));
        let l2 = layer_latency(&hw, &conv(16, 16, 8, 2));
        assert!((l8.dram_stall_cycles / l2.dram_stall_cycles - 4.0).abs() < 1e-9);
    }
}
