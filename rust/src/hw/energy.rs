//! Energy model: DSP switching + BRAM operand reads + DRAM weight traffic.
//!
//! E(layer) = dsp_pj * active_dsp_cycles
//!          + bram_pj * operand_line_reads
//!          + dram_pj_per_byte * weight_bytes
//!
//! Packing reduces active DSP cycles (fewer passes for the same MACs) and
//! reduces BRAM lines + DRAM bytes linearly in the bit-width — quantization
//! saves energy on all three terms, which is why the paper's composite
//! objective can trade accuracy against energy directly.

use super::latency::layer_latency;
use super::model::NetShape;
use super::HwConfig;

#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    pub dsp_uj: f64,
    pub bram_uj: f64,
    pub dram_uj: f64,
}

impl EnergyBreakdown {
    pub fn total_uj(&self) -> f64 {
        self.dsp_uj + self.bram_uj + self.dram_uj
    }
}

/// Per-image energy in microjoules.
pub fn energy_uj(hw: &HwConfig, net: &NetShape) -> EnergyBreakdown {
    let mut out = EnergyBreakdown::default();
    for l in &net.layers {
        let lat = layer_latency(hw, l);
        // Every compute cycle keeps the m*n DSP array switching.
        let dsp_cycles = lat.compute_cycles * (hw.m * hw.n) as f64;
        out.dsp_uj += hw.dsp_pj_per_cycle * dsp_cycles * 1e-6;
        // One BRAM operand line feeds each PE row per cycle; packed operands
        // share lines (bits/16 of a full line each).
        let line_reads =
            lat.compute_cycles * hw.n as f64 * (l.bits as f64 / 16.0);
        out.bram_uj += hw.bram_pj_per_access * line_reads * 1e-6;
        let bytes = l.weight_bits() as f64 / 8.0;
        out.dram_uj += hw.dram_pj_per_byte * bytes * 1e-6;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::model::{LayerKind, LayerShape};

    fn net(bits: u32) -> NetShape {
        NetShape {
            layers: vec![LayerShape {
                name: "c".into(),
                kind: LayerKind::Conv,
                ksize: 3,
                cin: 32,
                cout: 32,
                out_h: 8,
                out_w: 8,
                bits,
            }],
        }
    }

    #[test]
    fn quantization_saves_energy() {
        let hw = HwConfig::default();
        let e16 = energy_uj(&hw, &net(16)).total_uj();
        let e4 = energy_uj(&hw, &net(4)).total_uj();
        let e2 = energy_uj(&hw, &net(2)).total_uj();
        assert!(e4 < e16 / 2.0);
        assert!(e2 < e4);
    }

    #[test]
    fn breakdown_positive() {
        let e = energy_uj(&HwConfig::default(), &net(8));
        assert!(e.dsp_uj > 0.0 && e.bram_uj > 0.0 && e.dram_uj > 0.0);
        assert!((e.total_uj() - (e.dsp_uj + e.bram_uj + e.dram_uj)).abs() < 1e-12);
    }
}
