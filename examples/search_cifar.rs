//! Domain example: compress ResNet-18 on the CIFAR-100 proxy against a hard
//! size budget, comparing k-means TPE with every implemented baseline at the
//! same evaluation budget — a miniature Table II for one model.
//!
//! Run: `make artifacts && cargo run --release --example search_cifar [n_evals]`

use sammpq::coordinator::report::Table;
use sammpq::coordinator::{Algo, Leader, LeaderCfg, ObjectiveCfg};
use sammpq::hw::HwConfig;
use sammpq::runtime::Runtime;
use sammpq::train::ModelSession;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(10);
    let rt = Runtime::new()?;
    let sess = ModelSession::open(&rt, "resnet18-cifar100", 1024, 512)?;
    let (b16, w10) = sess.meta.resolve(|_| 16.0, |_| 1.0);
    let fp16_mb = sess.meta.net_shape(&b16, &w10).model_size_mb();

    let cfg = LeaderCfg {
        pretrain_steps: 100,
        n_evals: n,
        n_startup: (n / 3).max(3),
        final_steps: 120,
        objective: ObjectiveCfg {
            steps_per_eval: 8,
            eval_batches: 3,
            size_budget_mb: fp16_mb * 0.12, // ~ the paper's 11x compression point
            ..Default::default()
        },
        ..Default::default()
    };
    let leader = Leader::new(&sess, cfg, HwConfig::default());

    let mut t = Table::new(
        &format!("resnet18-cifar100 @ {:.3} MB budget, n={n}", fp16_mb * 0.12),
        &["algo", "final acc", "size MB", "speedup", "search s"],
    );
    for algo in [Algo::KmeansTpe, Algo::Tpe, Algo::Random, Algo::Evolutionary, Algo::Reinforce] {
        let r = leader.run(algo)?;
        t.row(vec![
            algo.name().to_string(),
            format!("{:.3}", r.final_accuracy),
            format!("{:.4}", r.final_size_mb),
            format!("{:.2}x", r.final_speedup),
            format!("{:.1}", r.search_secs),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
