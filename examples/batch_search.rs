//! Batched proposal + parallel evaluation on a real (PJRT-free) workload:
//! the Fig. 3b gradient-boosting hyperparameter search on Titanic.
//!
//! Demonstrates the three pieces of the batch engine together:
//!   * `BatchSearcher` — constant-liar rounds of q proposals,
//!   * `ParallelObjective` — each round fanned across thread-local replicas,
//!   * `CachedObjective` — duplicate proposals skip refits entirely.
//!
//! Run: `cargo run --release --example batch_search [q] [budget]`

use sammpq::exp::fig3::GbmTitanicObjective;
use sammpq::search::{
    BatchSearcher, CachedObjective, KmeansTpe, KmeansTpeParams, ParallelObjective, Searcher,
};
use sammpq::util::Timer;

fn main() {
    let q: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
    let budget: usize =
        std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(80).max(1);
    let params = KmeansTpeParams { n_startup: 20, seed: 0, ..Default::default() };

    // Sequential baseline: one proposal, one evaluation, repeat.
    let mut seq_obj = CachedObjective::new(GbmTitanicObjective::new(0));
    let t = Timer::start();
    let seq = KmeansTpe::new(params).run(&mut seq_obj, budget);
    let seq_secs = t.secs();

    // Batched: rounds of q constant-liar proposals, each round evaluated
    // across q thread-local objective replicas.
    let replicas: Vec<GbmTitanicObjective> =
        (0..q).map(|_| GbmTitanicObjective::new(0)).collect();
    let mut par_obj = CachedObjective::new(ParallelObjective::new(replicas));
    let t = Timer::start();
    let bat = BatchSearcher::kmeans_tpe(params, q).run(&mut par_obj, budget);
    let bat_secs = t.secs();

    println!("workload: GBM hyperparameters on Titanic (Fig. 3b), budget {budget}");
    println!(
        "sequential kmeans-tpe : best {:.4}  wall {:6.2}s  cache {}h/{}m",
        seq.best().unwrap().value,
        seq_secs,
        seq_obj.hits,
        seq_obj.misses,
    );
    println!(
        "batched q={q:<2}          : best {:.4}  wall {:6.2}s  cache {}h/{}m  ({:.2}x)",
        bat.best().unwrap().value,
        bat_secs,
        par_obj.hits,
        par_obj.misses,
        seq_secs / bat_secs.max(1e-9),
    );
    println!(
        "rounds: sequential {budget} (one eval each) vs batched {} (q evals each)",
        budget.div_ceil(q.max(1)),
    );

    // Adaptive q: the controller reads the observed eval/proposal cost
    // ratio (and the constant-liar diversification) and picks q per round.
    let replicas: Vec<GbmTitanicObjective> =
        (0..q).map(|_| GbmTitanicObjective::new(0)).collect();
    let mut auto_obj = CachedObjective::new(ParallelObjective::new(replicas));
    let mut auto = BatchSearcher::kmeans_tpe_auto(params);
    let t = Timer::start();
    let h = auto.run(&mut auto_obj, budget);
    let auto_secs = t.secs();
    let qs: Vec<usize> = auto.rounds.iter().map(|r| r.q).collect();
    println!(
        "adaptive q           : best {:.4}  wall {:6.2}s  q per round {qs:?}",
        h.best().unwrap().value,
        auto_secs,
    );
}
