//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Loads the ResNet-20/CIFAR-10-proxy artifact, pretrains briefly, runs a
//! tiny k-means TPE search under a model-size budget, and prints the
//! discovered configuration with its hardware metrics.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use sammpq::coordinator::{Algo, Leader, LeaderCfg, ObjectiveCfg};
use sammpq::exp::table4::render_config;
use sammpq::hw::HwConfig;
use sammpq::runtime::Runtime;
use sammpq::train::ModelSession;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new()?;
    println!("PJRT platform: {}", rt.platform());

    // One model session = compiled train/eval/hessian programs + proxy data.
    let sess = ModelSession::open(&rt, "resnet20-cifar10", 768, 384)?;
    println!(
        "model {} on {}: {} quantized layers, {} parameter tensors",
        sess.meta.model,
        sess.meta.dataset,
        sess.meta.num_layers,
        sess.meta.params.len()
    );

    // Budget: 20% of the FiP16 model size — the paper's compression regime.
    let (b16, w10) = sess.meta.resolve(|_| 16.0, |_| 1.0);
    let fp16_mb = sess.meta.net_shape(&b16, &w10).model_size_mb();

    let cfg = LeaderCfg {
        pretrain_steps: 80,
        n_evals: 12,
        n_startup: 5,
        final_steps: 100,
        objective: ObjectiveCfg {
            steps_per_eval: 8,
            eval_batches: 3,
            size_budget_mb: fp16_mb * 0.2,
            ..Default::default()
        },
        ..Default::default()
    };
    let report = Leader::new(&sess, cfg, HwConfig::default()).run(Algo::KmeansTpe)?;

    println!(
        "\nFiP16 baseline: acc {:.3}, {:.4} MB",
        report.baseline_accuracy, report.baseline_size_mb
    );
    println!(
        "ours:           acc {:.3}, {:.4} MB ({:.1}x smaller), {:.2}x faster",
        report.final_accuracy,
        report.final_size_mb,
        report.baseline_size_mb / report.final_size_mb,
        report.final_speedup
    );
    println!("\n{}", render_config(&report, &sess));
    Ok(())
}
