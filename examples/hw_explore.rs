//! Hardware-model exploration: sweep uniform bit-widths and width
//! multipliers over a model and print the size/latency/energy/speedup
//! surface — the §III-C cost model a user would consult before launching a
//! search. Includes the analytic-vs-simulator cross-check.
//!
//! Run: `make artifacts && cargo run --release --example hw_explore [tag]`

use sammpq::coordinator::report::Table;
use sammpq::hw::energy::energy_uj;
use sammpq::hw::sim::simulate;
use sammpq::hw::{baseline_latency_cycles, latency_cycles, HwConfig};
use sammpq::runtime::client::load_meta;

fn main() -> anyhow::Result<()> {
    let tag = std::env::args().nth(1).unwrap_or_else(|| "resnet18-cifar100".into());
    let meta = load_meta(&tag)?;
    let hw = HwConfig::default();

    let mut t = Table::new(
        &format!("cost surface — {tag}"),
        &["bits", "mult", "size MB", "lat ms", "sim ms", "speedup", "energy uJ", "util"],
    );
    for &bits in &[16.0, 8.0, 6.0, 4.0, 3.0, 2.0] {
        for &mult in &[0.75, 1.0, 1.25] {
            let (b, w) = meta.resolve(|_| bits, |_| mult);
            let net = meta.net_shape(&b, &w);
            let cycles = latency_cycles(&hw, &net);
            let base = baseline_latency_cycles(&hw, &net);
            let sim = simulate(&hw, &net);
            let e = energy_uj(&hw, &net);
            t.row(vec![
                format!("{bits:.0}"),
                format!("{mult}"),
                format!("{:.4}", net.model_size_mb()),
                format!("{:.4}", hw.cycles_to_ms(cycles)),
                format!("{:.4}", hw.cycles_to_ms(sim.total_cycles as f64)),
                format!("{:.2}x", base / cycles),
                format!("{:.1}", e.total_uj()),
                format!("{:.3}", sim.utilization),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "HiKonv packing (paper Fig. 2): 8/6b -> 2 MACs/DSP/cyc, 4/3b -> 6, 2b -> 15.\n\
         Speedup saturates at the packing factor; size scales linearly in bits."
    );
    Ok(())
}
