//! Fig. 3a/3b standalone: TPE vs k-means TPE on the classic-ML workloads.
//! Pure Rust — needs no artifacts, runs in seconds.
//!
//! Run: `cargo run --release --example convergence [paper]`

use sammpq::exp::fig3;
use sammpq::exp::Effort;

fn main() -> anyhow::Result<()> {
    let effort = std::env::args()
        .nth(1)
        .map(|s| Effort::parse(&s))
        .unwrap_or(Effort::Quick);
    let out = fig3::run_tabular(effort)?;
    println!("{out}");
    println!("CSV series written under results/fig3_*.csv");
    Ok(())
}
