//! Async straggler-tolerant worker pool, in-process edition: four TCP
//! worker threads serve the synthetic objective — one of them 10x slower —
//! and an adaptive-q batched k-means TPE search runs through the pool.
//! Watch the round log: rounds keep near-all-fast wall-clock because the
//! straggler's configs are re-dispatched to idle workers (first result
//! wins), and q tracks the eval/proposal cost ratio.
//!
//! The multi-process equivalent is `sammpq worker --synthetic` plus
//! `sammpq pool` (see the CLI help).
//!
//! Run: `cargo run --release --example async_pool [budget]`

use std::net::TcpListener;
use std::time::Duration;

use sammpq::coordinator::service::{serve_worker_on, PoolCfg, RemoteObjective, SessionSpec,
                                   SyntheticBackend};
use sammpq::search::{BatchSearcher, KmeansTpeParams, Objective, Searcher, SyntheticObjective};
use sammpq::util::Timer;

fn main() -> anyhow::Result<()> {
    let budget: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(48).max(1);
    let sleeps_ms = [200u64, 20, 20, 20]; // worker 0 is the straggler

    let mut addrs = Vec::new();
    let mut joins = Vec::new();
    for &ms in &sleeps_ms {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(listener.local_addr()?.to_string());
        joins.push(std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut backend = SyntheticBackend::new(8, 4, Duration::from_millis(ms));
            serve_worker_on(stream, &mut backend).expect("worker")
        }));
    }
    println!("pool: {} workers, per-eval sleeps {sleeps_ms:?} ms", addrs.len());

    let space = SyntheticObjective::new(8, 4, Duration::ZERO).space().clone();
    let mut remote = RemoteObjective::connect_session(
        SessionSpec::synthetic(space),
        &addrs,
        PoolCfg::default(),
    )?;
    let params = KmeansTpeParams { n_startup: 12, seed: 0, ..Default::default() };
    let mut searcher = BatchSearcher::kmeans_tpe_auto(params);
    let t = Timer::start();
    let h = searcher.run(&mut remote, budget);
    let wall = t.secs();
    remote.shutdown()?;
    for (w, j) in joins.into_iter().enumerate() {
        println!("worker {w} served {} evaluations", j.join().unwrap());
    }

    println!(
        "best {:.1} after {} evals in {wall:.2}s — {} rounds, {} straggler \
         re-dispatches, {} requeues",
        h.best().unwrap().value,
        h.len(),
        searcher.rounds.len(),
        remote.pool.redispatched,
        remote.pool.requeued,
    );
    for (i, r) in searcher.rounds.iter().enumerate() {
        println!(
            "round {i:>2}: q={} distinct={} eval {:>5.0} ms{}",
            r.q,
            r.distinct,
            r.eval_secs * 1e3,
            if r.startup { " (startup)" } else { "" },
        );
    }
    Ok(())
}
