//! Distributed search sessions end-to-end, no artifacts needed: two TCP
//! worker threads serve the synthetic objective, the leader opens a
//! versioned session (space-sync handshake + snapshot digest), runs a
//! batched k-means TPE search collecting full record-return replies,
//! checkpoints every round — then "crashes", and resumes from the
//! checkpoint to a history identical to an uninterrupted run.
//!
//! The multi-process equivalent:
//!
//!   sammpq worker --synthetic 6x4 --addr 127.0.0.1:7447
//!   sammpq worker --synthetic 6x4 --addr 127.0.0.1:7448
//!   sammpq search --workers 127.0.0.1:7447,127.0.0.1:7448 \
//!       --checkpoint search.ckpt ...     # and later: --resume search.ckpt
//!
//! Run: `cargo run --release --example remote_search`

use std::net::TcpListener;
use std::time::Duration;

use sammpq::coordinator::service::{serve_on_listener, SyntheticBackend};
use sammpq::coordinator::{PoolCfg, RemoteObjective, SessionSpec};
use sammpq::search::{BatchSearcher, KmeansTpeParams, Objective, SearchCheckpoint,
                     SyntheticObjective};
use sammpq::util::json::Json;

fn spawn_worker() -> anyhow::Result<(String, std::thread::JoinHandle<usize>)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let handle = std::thread::spawn(move || {
        // Workers start on a DIFFERENT default space (8x4); the session
        // handshake rebuilds them onto the leader's 6x4 space.
        let mut backend = SyntheticBackend::new(8, 4, Duration::from_millis(5));
        serve_on_listener(listener, &mut backend).expect("worker")
    });
    Ok((addr, handle))
}

fn main() -> anyhow::Result<()> {
    let budget = 36;
    let space = SyntheticObjective::new(6, 4, Duration::ZERO).space().clone();
    let params = KmeansTpeParams { n_startup: 12, seed: 0, ..Default::default() };
    let searcher = BatchSearcher::kmeans_tpe(params, 4);

    // --- Session 1: search until the "crash", checkpointing every round.
    let (a1, h1) = spawn_worker()?;
    let (a2, h2) = spawn_worker()?;
    let mut remote = RemoteObjective::connect_session(
        SessionSpec::synthetic(space.clone()),
        &[a1, a2],
        PoolCfg::default(),
    )?;
    println!("session 1: 2 workers space-synced to {} dims", space.num_dims());

    let mut run = searcher.start(space.clone(), budget, None)?;
    let mut checkpoint_json = String::new();
    while run.history().len() < budget / 2 {
        run.step(&mut remote);
        checkpoint_json = run.checkpoint().to_json().to_string_pretty();
        println!(
            "  round done: {} / {budget} trials (checkpoint {} bytes)",
            run.history().len(),
            checkpoint_json.len()
        );
    }
    drop(run); // the crash: searcher state is gone...
    remote.shutdown()?;
    println!("session 1 'crashed' — workers served {} + {}", h1.join().unwrap(), h2.join().unwrap());

    // --- Session 2: fresh workers, resume from the serialized checkpoint.
    let ck = SearchCheckpoint::from_json(&Json::parse(&checkpoint_json).unwrap())?;
    let (a3, h3) = spawn_worker()?;
    let mut remote = RemoteObjective::connect_session(
        SessionSpec::synthetic(space.clone()),
        std::slice::from_ref(&a3),
        PoolCfg::default(),
    )?;
    let mut resumed = searcher.start(space.clone(), budget, Some(&ck))?;
    while !resumed.done() {
        resumed.step(&mut remote);
    }
    let resumed_hist = resumed.finish().0;
    remote.shutdown()?;
    println!("session 2 resumed {} -> {} trials ({} served)", ck.history.len(), resumed_hist.len(), h3.join().unwrap());
    println!(
        "records collected remotely: {} (all values worker-computed)",
        remote.log.len()
    );

    // --- Reference: the uninterrupted run (in-process) matches exactly.
    let mut local = SyntheticObjective::with_space(space.clone(), Duration::ZERO);
    let mut full = searcher.start(space, budget, None)?;
    while !full.done() {
        full.step(&mut local);
    }
    let full_hist = full.finish().0;
    let identical = full_hist.values() == resumed_hist.values()
        && full_hist
            .trials
            .iter()
            .zip(&resumed_hist.trials)
            .all(|(a, b)| a.config == b.config);
    println!(
        "resumed history identical to uninterrupted run: {identical} \
         (best {:.1})",
        resumed_hist.best().unwrap().value
    );
    anyhow::ensure!(identical, "resume diverged");
    Ok(())
}
