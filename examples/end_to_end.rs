//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the stack on a real small workload and proves
//! they compose:
//!   L1 Pallas kernels  — live inside the compiled programs (fake-quant +
//!                        fused quantized matmul lower into the HLO),
//!   L2 JAX model       — ResNet-20 QAT train/eval/hessian programs,
//!   L3 Rust coordinator— data synthesis, OneCycle QAT training loop with a
//!                        logged loss curve, Hessian pruning, k-means TPE
//!                        search, hardware model, final training.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use sammpq::coordinator::{Algo, Leader, LeaderCfg, ObjectiveCfg};
use sammpq::hw::HwConfig;
use sammpq::runtime::Runtime;
use sammpq::train::ModelSession;
use sammpq::util::Timer;

fn main() -> anyhow::Result<()> {
    let t_all = Timer::start();
    let rt = Runtime::new()?;
    println!("[1/4] PJRT platform: {}", rt.platform());

    let sess = ModelSession::open(&rt, "resnet20-cifar10", 2048, 512)?;
    println!(
        "[1/4] artifacts compiled: {} ({} layers, {} param tensors, batch {})",
        sess.tag,
        sess.meta.num_layers,
        sess.meta.params.len(),
        sess.meta.batch
    );

    // -- Training run with logged loss curve --------------------------------
    let snap = sess.init_snapshot(42);
    let mut state = sess.state_from_snapshot(&snap)?;
    let bits = sess.meta.uniform_bits(8.0);
    let widths = sess.meta.base_widths();
    let steps = 300;
    let t_train = Timer::start();
    let out = sess.train(&mut state, &bits, &widths, steps, 3e-3)?;
    let secs = t_train.secs();
    println!(
        "[2/4] QAT training: {steps} steps in {secs:.1}s ({:.0} ms/step)",
        secs * 1e3 / steps as f64
    );
    print!("      loss curve: ");
    for s in (0..steps).step_by(steps / 10) {
        print!("{:.2} ", out.losses[s]);
    }
    println!("-> {:.3}", out.final_loss);
    let acc = sess.evaluate(&state, &bits, &widths, 8)?;
    println!("      val accuracy after {steps} steps @8b: {acc:.3}");
    anyhow::ensure!(acc > 0.5, "end-to-end training failed to learn (acc {acc})");

    // -- Full pipeline: prune + search + final train -------------------------
    let (b16, w10) = sess.meta.resolve(|_| 16.0, |_| 1.0);
    let fp16_mb = sess.meta.net_shape(&b16, &w10).model_size_mb();
    let cfg = LeaderCfg {
        pretrain_steps: 150,
        n_evals: 16,
        n_startup: 6,
        final_steps: 600,
        objective: ObjectiveCfg {
            steps_per_eval: 24,
            eval_batches: 4,
            size_budget_mb: fp16_mb * 0.25,
            ..Default::default()
        },
        ..Default::default()
    };
    println!("[3/4] Alg.1 pipeline: pretrain -> hessian prune -> kmeans-tpe -> final");
    let report = Leader::new(&sess, cfg, HwConfig::default()).run(Algo::KmeansTpe)?;
    if let Some(p) = &report.pruned {
        let (before, after) = p.log10_reduction();
        println!("      pruning: bit-space 10^{before:.1} -> 10^{after:.1}");
    }
    println!(
        "      search: {} evals in {:.1}s; best J = {:.4}",
        report.history.len(),
        report.search_secs,
        report.best.value
    );
    println!(
        "[4/4] RESULT  baseline: acc {:.3} @ {:.4} MB | ours: acc {:.3} @ {:.4} MB, {:.2}x speedup",
        report.baseline_accuracy,
        report.baseline_size_mb,
        report.final_accuracy,
        report.final_size_mb,
        report.final_speedup
    );
    let compression = report.baseline_size_mb / report.final_size_mb;
    anyhow::ensure!(compression > 3.0, "compression too weak: {compression:.2}x");
    anyhow::ensure!(
        report.final_accuracy > report.baseline_accuracy - 0.30,
        "accuracy collapsed (final {} vs baseline {})",
        report.final_accuracy,
        report.baseline_accuracy
    );
    println!(
        "\nEND-TO-END OK: {:.1}x compression at {:+.3} accuracy delta, total {:.0}s",
        compression,
        report.final_accuracy - report.baseline_accuracy,
        t_all.secs()
    );
    Ok(())
}
