//! Offline stub of the `xla` (xla-rs) PJRT surface used by `sammpq`.
//!
//! The PJRT C API + XLA runtime are not available in this build environment,
//! so this crate keeps the workspace compiling and the non-runtime 95% of the
//! system (search, hardware model, coordinator, mlbase, experiments)
//! testable. `Literal` is a real host-side buffer implementation; everything
//! that would require an actual compiler/executor (`HloModuleProto` parsing,
//! `PjRtClient::compile`, `PjRtLoadedExecutable::execute`) returns a clear
//! runtime error. Swap this path dependency for the real `xla` crate to light
//! up the PJRT-backed paths — the API is call-compatible for the surface the
//! workspace uses.

use std::borrow::Borrow;
use std::path::Path;

/// Error type; formatted with `{:?}` at every call site in the workspace.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub_err(what: &str) -> XlaError {
    XlaError(format!(
        "{what} unavailable: the `xla` dependency is the offline stub \
         (vendor/xla); build against the real xla-rs crate to execute HLO \
         artifacts"
    ))
}

// ---------------------------------------------------------------------------
// Literal: a real host-side implementation (data shuttling needs no runtime).
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A typed host buffer with a shape, mirroring `xla::Literal`.
#[derive(Debug, Clone)]
pub struct Literal {
    buf: Buf,
    dims: Vec<i64>,
}

/// Element types storable in a [`Literal`].
pub trait NativeType: Copy {
    fn make_buf(data: &[Self]) -> Buf;
    fn extract(buf: &Buf) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn make_buf(data: &[Self]) -> Buf {
        Buf::F32(data.to_vec())
    }
    fn extract(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn make_buf(data: &[Self]) -> Buf {
        Buf::I32(data.to_vec())
    }
    fn extract(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { buf: T::make_buf(data), dims: vec![data.len() as i64] }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { buf: T::make_buf(&[v]), dims: Vec::new() }
    }

    pub fn element_count(&self) -> usize {
        match &self.buf {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
            Buf::Tuple(t) => t.len(),
        }
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(XlaError(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { buf: self.buf.clone(), dims: dims.to_vec() })
    }

    /// Extract the host data (fails on element-type mismatch or tuples).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.buf).ok_or_else(|| XlaError("to_vec: element type mismatch".into()))
    }

    /// Split a tuple literal into its elements.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.buf, Buf::Tuple(Vec::new())) {
            Buf::Tuple(elems) => Ok(elems),
            other => {
                self.buf = other;
                Err(XlaError("decompose_tuple: not a tuple literal".into()))
            }
        }
    }

    /// Build a tuple literal (handy for tests of tuple decomposition).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        let n = elems.len() as i64;
        Literal { buf: Buf::Tuple(elems), dims: vec![n] }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---------------------------------------------------------------------------
// PJRT surface: type-compatible, runtime-unavailable.
// ---------------------------------------------------------------------------

/// Parsed HLO module. The stub cannot parse HLO text, so instances are
/// unconstructible in practice (`from_text_file` always errors).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(XlaError(format!(
            "parse {}: {}",
            path.as_ref().display(),
            stub_err("HLO text parsing")
        )))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. Construction succeeds (so `Runtime::new` works and
/// callers can print the platform), but compilation reports the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu (no PJRT)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PJRT compilation"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PJRT execution"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_decompose() {
        let mut t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        let elems = t.decompose_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        assert_eq!(elems[0].to_vec::<f32>().unwrap(), vec![1.0]);
        let mut s = Literal::scalar(3.0f32);
        assert!(s.decompose_tuple().is_err());
        // Non-tuple literal survives a failed decompose.
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![3.0]);
    }

    #[test]
    fn pjrt_paths_error_clearly() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = c.compile(&XlaComputation { _private: () }).unwrap_err();
        assert!(format!("{err:?}").contains("offline stub"));
    }
}
