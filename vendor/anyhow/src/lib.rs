//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access and no guarantee that the
//! offline registry carries `anyhow`, so this shim provides exactly the
//! surface the workspace uses: `Error`, `Result<T>`, the `Context` extension
//! trait for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Error state is a flattened message chain (outermost first);
//! `{}` prints the outermost message, `{:#}` the full `a: b: c` chain,
//! matching the upstream Display contract closely enough for logs and tests.
//!
//! If the real `anyhow` becomes available, drop this directory and point the
//! workspace dependency back at the registry — no source changes needed.

use std::fmt;

/// A type-erased error: a chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context(...)` attaches).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, so this
// blanket `From` cannot overlap std's reflexive `impl From<T> for T`
// (the same coherence trick upstream anyhow relies on).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::from(io_err()).context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert!(format!("{e:#}").starts_with("reading config: "));

        let o: Option<u8> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too large: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too large: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }
}
