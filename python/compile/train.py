"""Program builders for the three PJRT artifacts exported per model.

Every program takes and returns FLAT positional arrays (no pytrees) in a fixed
documented order, so the Rust runtime can marshal literals without a pytree
library. The orders are recorded in meta.json by `aot.py`.

  train_step : (*params, *m, *v, t, x, y, bits, widths, lr, wd)
            -> (*params', *m', *v', loss)
     One Adam/QAT step (paper trains with Adam; the OneCycleLR schedule is
     implemented by the Rust trainer, which passes `lr` per step).

  eval_batch : (*params, x, y, bits, widths) -> (correct, loss)

  hessian_trace : (*params, x, y, widths, seed) -> vHv[f32[L]]
     One Hutchinson sample of the per-layer Hessian-trace: a single Rademacher
     tangent over ALL decayed conv/fc kernels at once; per-layer vT(Hv) is an
     unbiased estimate of tr(H_ll) (cross-layer terms vanish in expectation).
     Runs on the FP graph (quant=False): matches the paper (sensitivity of the
     full-precision pretrained model) and keeps forward-mode AD legal (the STE
     custom_vjp does not support jvp).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .models.common import Model

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def cross_entropy(logits: jax.Array, y: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32),
                                         axis=1))


def build_train_step(model: Model):
    n = len(model.params)
    decay_flags = [p.decay for p in model.params]

    def train_step(*args):
        params = list(args[0:n])
        m = list(args[n:2 * n])
        v = list(args[2 * n:3 * n])
        t, x, y, bits, widths, lr, wd = args[3 * n:3 * n + 7]

        def loss_fn(ps):
            logits = model.apply(ps, x, bits, widths, quant=True)
            return cross_entropy(logits, y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        t1 = t + 1.0
        bc1 = 1.0 - ADAM_B1 ** t1
        bc2 = 1.0 - ADAM_B2 ** t1
        new_p, new_m, new_v = [], [], []
        for pi, mi, vi, gi, dec in zip(params, m, v, grads, decay_flags):
            mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * gi
            vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * gi * gi
            step = mi / bc1 / (jnp.sqrt(vi / bc2) + ADAM_EPS)
            if dec:
                step = step + wd * pi
            new_p.append(pi - lr * step)
            new_m.append(mi)
            new_v.append(vi)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    return train_step


def build_eval_batch(model: Model):
    n = len(model.params)

    def eval_batch(*args):
        params = list(args[0:n])
        x, y, bits, widths = args[n:n + 4]
        logits = model.apply(params, x, bits, widths, quant=True)
        loss = cross_entropy(logits, y)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return (correct, loss)

    return eval_batch


def build_hessian_trace(model: Model):
    n = len(model.params)
    decay_flags = [p.decay for p in model.params]
    # Map each decayed kernel param to the quantized layer it belongs to, by
    # construction order: layer metas and kernel params are appended in the
    # same order in the builders.
    kernel_param_ids = [i for i, d in enumerate(decay_flags) if d]
    nl = model.num_layers
    # fc bias excluded (decay=False); fc weight included -> len == num layers.
    assert len(kernel_param_ids) == nl, (len(kernel_param_ids), nl)

    def hessian_trace(*args):
        params = list(args[0:n])
        x, y, widths, seed = args[n:n + 4]

        def loss_fn(ps):
            logits = model.apply(ps, x, bits=jnp.full((nl,), 16.0),
                                 widths=widths, quant=False)
            return cross_entropy(logits, y)

        key = jax.random.PRNGKey(seed.astype(jnp.int32))
        keys = jax.random.split(key, len(kernel_param_ids))
        tangents = [jnp.zeros_like(p) for p in params]
        vs = {}
        for kk, pid in zip(keys, kernel_param_ids):
            rv = jax.random.rademacher(kk, params[pid].shape).astype(jnp.float32)
            tangents[pid] = rv
            vs[pid] = rv

        grad_fn = jax.grad(loss_fn)
        _, hv = jax.jvp(grad_fn, (params,), (tangents,))
        ests = [jnp.sum(vs[pid] * hv[pid]) for pid in kernel_param_ids]
        return (jnp.stack(ests),)

    return hessian_trace
