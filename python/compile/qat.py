"""Quantization-aware-training primitives: straight-through estimators (STE)
around the L1 Pallas kernels.

`round()` has zero gradient almost everywhere, so QAT backpropagates through
fake quantization with the straight-through estimator: forward = the Pallas
kernel, backward = identity on the real-valued operand. For the fused
quantize->matmul kernel the backward pass uses the *quantized* operands
(recomputed with the `ref.py` formulas, which the kernel test-suite pins to be
identical to the kernel's own quantization), i.e.

    dL/dx = g @ fq(w)^T        dL/dw = fq(x)^T @ g

which is the exact gradient of the forward computation under STE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import fake_quant as fq_kernel
from .kernels import qmatmul as qmm_kernel
from .kernels import ref


@jax.custom_vjp
def fake_quant_ste(x, bits):
    """STE fake quantization. x: any f32 tensor; bits: f32[1] runtime array."""
    return fq_kernel.fake_quant(x, bits)


def _fq_fwd(x, bits):
    return fq_kernel.fake_quant(x, bits), None


def _fq_bwd(_, g):
    # Identity STE (max-calibrated symmetric quant never clips, so no mask).
    return g, jnp.zeros((1,), dtype=jnp.float32)


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)


@jax.custom_vjp
def qmatmul_ste(x, w, bits_x, bits_w):
    """STE fused quantized matmul: fq(x) @ fq(w), Pallas-tiled forward."""
    sx = ref.quant_scale(x, bits_x)
    sw = ref.quant_scale(w, bits_w)
    return qmm_kernel.qmatmul(x, w, sx, sw, bits_x, bits_w)


def _qmm_fwd(x, w, bits_x, bits_w):
    sx = ref.quant_scale(x, bits_x)
    sw = ref.quant_scale(w, bits_w)
    out = qmm_kernel.qmatmul(x, w, sx, sw, bits_x, bits_w)
    return out, (x, w, sx, sw, bits_x, bits_w)


def _qmm_bwd(res, g):
    x, w, sx, sw, bx, bw = res
    xq = ref.fake_quant_with_scale_ref(x, sx, bx)
    wq = ref.fake_quant_with_scale_ref(w, sw, bw)
    dx = g @ wq.T
    dw = xq.T @ g
    zero = jnp.zeros((), dtype=jnp.float32)
    return dx, dw, zero, zero


qmatmul_ste.defvjp(_qmm_fwd, _qmm_bwd)
