"""L2 façade: re-exports the model registry + program builders.

The canonical entry points live in `models/registry.py` (architectures) and
`train.py` (train/eval/hessian program builders); this module keeps the
documented `python/compile/model.py` path stable for downstream users.
"""

from .models.registry import BUILDERS, EXPORTS, build  # noqa: F401
from .train import (build_train_step, build_eval_batch,  # noqa: F401
                    build_hessian_trace, cross_entropy)
