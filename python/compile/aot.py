"""AOT pipeline: lower every exported program to HLO TEXT + write meta.json.

Runs exactly once (`make artifacts`); Python is never on the search path.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts layout:
  artifacts/<model>-<dataset>/train_step.hlo.txt
  artifacts/<model>-<dataset>/eval_batch.hlo.txt
  artifacts/<model>-<dataset>/hessian_trace.hlo.txt
  artifacts/<model>-<dataset>/meta.json
  artifacts/kernels/{fake_quant_bench,qmatmul_bench}.hlo.txt   (L1 micro-bench)
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .models import registry
from .models.common import WIDTH_MULTS
from . import train as train_mod
from .kernels import fake_quant as fq_kernel
from .kernels import qmatmul as qmm_kernel

BATCH = 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) // 1024} KiB)")


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_model(model_name: str, dataset: str, num_classes: int,
                 out_root: str) -> None:
    model = registry.build(model_name, num_classes)
    tag = f"{model_name}-{dataset}"
    out_dir = os.path.join(out_root, tag)
    os.makedirs(out_dir, exist_ok=True)
    print(f"[aot] exporting {tag}: {len(model.params)} params, "
          f"{model.num_layers} quantized layers")

    n = len(model.params)
    nl = model.num_layers
    hw = model.image_hw
    p_specs = [spec(p.shape) for p in model.params]
    x_spec = spec((BATCH, hw, hw, 3))
    y_spec = spec((BATCH,), jnp.int32)
    bits_spec = spec((nl,))
    widths_spec = spec((nl,))
    scalar = spec(())

    train_step = train_mod.build_train_step(model)
    train_args = (p_specs + p_specs + p_specs +
                  [scalar, x_spec, y_spec, bits_spec, widths_spec, scalar,
                   scalar])
    lower_to_file(train_step, train_args,
                  os.path.join(out_dir, "train_step.hlo.txt"))

    eval_batch = train_mod.build_eval_batch(model)
    eval_args = (p_specs + [x_spec, y_spec, bits_spec, widths_spec])
    lower_to_file(eval_batch, eval_args,
                  os.path.join(out_dir, "eval_batch.hlo.txt"))

    hess = train_mod.build_hessian_trace(model)
    hess_args = (p_specs + [x_spec, y_spec, widths_spec,
                            spec((), jnp.int32)])
    lower_to_file(hess, hess_args,
                  os.path.join(out_dir, "hessian_trace.hlo.txt"))

    meta = {
        "model": model_name,
        "dataset": dataset,
        "num_classes": num_classes,
        "image_hw": hw,
        "batch": BATCH,
        "num_layers": nl,
        "width_mults": WIDTH_MULTS,
        "params": [dict(name=p.name, shape=list(p.shape), init=p.init,
                        fan_in=p.fan_in, decay=p.decay)
                   for p in model.params],
        "layers": [dict(index=l.index, name=l.name, kind=l.kind, ksize=l.ksize,
                        stride=l.stride, in_base=l.in_base, out_base=l.out_base,
                        cmax_in=l.cmax_in, cmax_out=l.cmax_out, out_h=l.out_h,
                        out_w=l.out_w, width_tie=l.width_tie,
                        bits_tie=l.bits_tie, width_fixed=l.width_fixed,
                        bits_free=l.bits_free)
                   for l in model.layers],
        "programs": {
            "train_step": {
                "inputs": "params*%d, m*%d, v*%d, t, x[%d,%d,%d,3], y[i32,%d], bits[%d], widths[%d], lr, wd"
                          % (n, n, n, BATCH, hw, hw, BATCH, nl, nl),
                "outputs": "params*%d, m*%d, v*%d, loss" % (n, n, n),
            },
            "eval_batch": {
                "inputs": "params*%d, x, y, bits, widths" % n,
                "outputs": "correct, loss",
            },
            "hessian_trace": {
                "inputs": "params*%d, x, y, widths, seed[i32]" % n,
                "outputs": "vHv[f32[%d]]" % nl,
            },
        },
        "adam": {"b1": train_mod.ADAM_B1, "b2": train_mod.ADAM_B2,
                 "eps": train_mod.ADAM_EPS},
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)


def export_kernel_benches(out_root: str) -> None:
    """Standalone L1 kernel artifacts for the Rust-side micro-benchmarks."""
    out_dir = os.path.join(out_root, "kernels")
    os.makedirs(out_dir, exist_ok=True)

    def fq_bench(x, bits):
        return (fq_kernel.fake_quant(x, bits),)

    lower_to_file(fq_bench, [spec((256, 1024)), spec((1,))],
                  os.path.join(out_dir, "fake_quant_bench.hlo.txt"))

    def qmm_bench(x, w, s):
        return (qmm_kernel.qmatmul(x, w, s[0], s[1], s[2], s[3]),)

    lower_to_file(qmm_bench, [spec((256, 256)), spec((256, 128)), spec((4,))],
                  os.path.join(out_dir, "qmatmul_bench.hlo.txt"))

    # Pure-jnp reference matmul of the same shape: the roofline comparator
    # for EXPERIMENTS.md §Perf (kernel vs XLA-native efficiency ratio).
    def mm_ref(x, w):
        return (x @ w,)

    lower_to_file(mm_ref, [spec((256, 256)), spec((256, 128))],
                  os.path.join(out_dir, "matmul_ref_bench.hlo.txt"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated model-dataset tags to export")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    export_kernel_benches(args.out)
    for model_name, dataset, classes in registry.EXPORTS:
        tag = f"{model_name}-{dataset}"
        if only is not None and tag not in only:
            continue
        export_model(model_name, dataset, classes, args.out)
    print("[aot] done")


if __name__ == "__main__":
    main()
