"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness contracts: `python/tests/test_kernels.py`
asserts `allclose(kernel(...), ref(...))` across a hypothesis-style sweep of
shapes / bit-widths / value ranges, and the QAT straight-through backward pass
(qat.py) recomputes quantized operands with these formulas, so kernel<->ref
agreement is what makes training gradients consistent with the forward pass.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_levels(bits: jax.Array) -> jax.Array:
    """Number of positive quantization levels for a symmetric b-bit grid."""
    return jnp.exp2(bits - 1.0) - 1.0


def quant_scale(x: jax.Array, bits: jax.Array) -> jax.Array:
    """Per-tensor max-calibrated scale; 1.0 for all-zero tensors."""
    amax = jnp.max(jnp.abs(x))
    return jnp.where(amax > 0.0, amax / quant_levels(bits), 1.0)


def fake_quant_ref(x: jax.Array, bits: jax.Array) -> jax.Array:
    """Oracle for kernels.fake_quant.fake_quant (bits: f32[1] or scalar)."""
    b = jnp.reshape(bits, (-1,))[0]
    levels = quant_levels(b)
    scale = quant_scale(x, b)
    q = jnp.clip(jnp.round(x / scale), -levels, levels)
    return q * scale


def fake_quant_with_scale_ref(x: jax.Array, scale: jax.Array, bits: jax.Array) -> jax.Array:
    """Quantize with an externally supplied per-tensor scale (qmatmul path)."""
    levels = quant_levels(bits)
    q = jnp.clip(jnp.round(x / scale), -levels, levels)
    return q * scale


def qmatmul_ref(x: jax.Array, w: jax.Array, scale_x: jax.Array, scale_w: jax.Array,
                bits_x: jax.Array, bits_w: jax.Array) -> jax.Array:
    """Oracle for kernels.qmatmul.qmatmul."""
    xq = fake_quant_with_scale_ref(x, scale_x, bits_x)
    wq = fake_quant_with_scale_ref(w, scale_w, bits_w)
    return xq @ wq
