"""L1 Pallas kernel: tiled fused quantize->matmul.

The paper's accelerator packs low-bit operands so one DSP performs multiple
MACs; on TPU the analogous schedule is: stream HBM tiles into VMEM, quantize
*in VMEM*, and feed the MXU one (bm x bk)@(bk x bn) systolic pass per tile
(DESIGN.md §Hardware-Adaptation). This kernel implements that schedule.

It computes  fq(x, sx, bx) @ fq(w, sw, bw)  where the per-tensor scales
(sx, sw) are computed by the caller over the FULL tensors (so tiling does not
change numerics vs. the per-tensor oracle in `ref.py`) and the bit-widths are
runtime scalars.

Used by L2 for dense heads and MobileNet pointwise (1x1) convolutions — the
matmul-shaped layers that dominate those models.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile shapes: MXU-aligned on TPU would be (128, 128); interpret-mode CPU
# emulation favours fewer grid steps, so tiles are chosen per call-site as the
# largest divisor <= MAX_TILE.
MAX_TILE_M = 256
MAX_TILE_N = 128


def _quant(v, scale, bits):
    levels = jnp.exp2(bits - 1.0) - 1.0
    q = jnp.clip(jnp.round(v / scale), -levels, levels)
    return q * scale


def _qmatmul_kernel(s_ref, x_ref, w_ref, o_ref):
    """One (bm x bn) output tile: quantize both VMEM-resident operand tiles,
    then a single MXU-shaped dot. s_ref = [sx, sw, bx, bw] broadcast to all
    grid cells."""
    sx, sw, bx, bw = s_ref[0], s_ref[1], s_ref[2], s_ref[3]
    xq = _quant(x_ref[...], sx, bx)
    wq = _quant(w_ref[...], sw, bw)
    o_ref[...] = jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def _largest_divisor(n: int, cap: int) -> int:
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def qmatmul(x: jax.Array, w: jax.Array, scale_x: jax.Array, scale_w: jax.Array,
            bits_x: jax.Array, bits_w: jax.Array) -> jax.Array:
    """Tiled fused quantized matmul.

    Args:
      x: f32[M, K] activations.  w: f32[K, N] weights.
      scale_x / scale_w: f32[] per-tensor scales (full-tensor max / levels).
      bits_x / bits_w:   f32[] runtime bit-widths.

    Returns: f32[M, N] = fq(x) @ fq(w).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    bm = _largest_divisor(m, MAX_TILE_M)
    bn = _largest_divisor(n, MAX_TILE_N)
    s = jnp.stack([scale_x, scale_w, bits_x, bits_w]).astype(jnp.float32)
    return pl.pallas_call(
        _qmatmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((4,), lambda i, j: (0,)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(s, x, w)


def qmatmul_vmem_bytes(m: int, k: int, n: int) -> int:
    """Per-grid-step VMEM footprint (x tile + w tile + out tile), f32."""
    bm = _largest_divisor(m, MAX_TILE_M)
    bn = _largest_divisor(n, MAX_TILE_N)
    return 4 * (bm * k + k * bn + bm * bn)


def qmatmul_mxu_passes(m: int, k: int, n: int) -> int:
    """Number of 128x128x128 MXU systolic passes the tiled schedule issues —
    the utilization estimator used in DESIGN.md / EXPERIMENTS.md §Perf."""
    ceil = lambda a, b: -(-a // b)
    return ceil(m, 128) * ceil(k, 128) * ceil(n, 128)
