"""L1 Pallas kernel: symmetric per-tensor fake quantization with a *runtime* bit-width.

This is the QAT hot-spot of the paper: every quantized layer fake-quantizes both
its weights and its input activations to the layer's searched bit-width. The
bit-width arrives as a runtime scalar (f32) so that ONE lowered HLO artifact
serves every point of the search space — the Rust coordinator never re-lowers.

Quantization scheme (matches `ref.fake_quant_ref` exactly):
    levels = 2^(b-1) - 1                 (symmetric, no zero-point)
    scale  = max(|x|) / levels           (per-tensor, max-calibrated)
    q      = clip(round(x / scale), -levels, levels)
    out    = q * scale

The kernel runs as a single VMEM block (grid=()) — weight/activation tensors at
CIFAR scale fit comfortably; on a real TPU the same kernel tiles via BlockSpec
(see `qmatmul.py` for the tiled pattern). `interpret=True` is mandatory on this
image: real TPU lowering emits a Mosaic custom-call the CPU PJRT client cannot
execute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fake_quant_kernel(x_ref, bits_ref, o_ref):
    """Kernel body: quantize the whole block resident in VMEM."""
    x = x_ref[...]
    b = bits_ref[0]
    levels = jnp.exp2(b - 1.0) - 1.0
    amax = jnp.max(jnp.abs(x))
    # Guard: all-zero tensors (e.g. fully masked channels) keep scale 1.0.
    scale = jnp.where(amax > 0.0, amax / levels, 1.0)
    q = jnp.clip(jnp.round(x / scale), -levels, levels)
    o_ref[...] = q * scale


def fake_quant(x: jax.Array, bits: jax.Array) -> jax.Array:
    """Fake-quantize `x` to `bits` bits (runtime value).

    Args:
      x:    any-shape f32 tensor.
      bits: f32[1] — bit-width as a runtime scalar array. Values >= 16
            are numerically near-identity (used for the FP16 baseline).

    Returns:
      f32 tensor of the same shape, quantized-then-dequantized.
    """
    return pl.pallas_call(
        _fake_quant_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, bits)


def fake_quant_vmem_bytes(shape, dtype_bytes: int = 4) -> int:
    """VMEM footprint estimate for the single-block kernel (in + out)."""
    n = 1
    for d in shape:
        n *= d
    return 2 * n * dtype_bytes
