"""MobileNetV1 / MobileNetV2 (CIFAR-style) with searchable bits + widths.

MobileNetV1: stem conv + 13 (depthwise, pointwise) pairs + fc. Depthwise
layers share their channel set with the producing pointwise layer, so their
width ties to it (width not free) but their BIT-WIDTH is a free dimension —
matching the paper's MobileNetV1 config vector, which assigns bits to dw and
pw layers separately.

MobileNetV2: inverted residual blocks (expand pw -> dw -> project pw).
Expansion width is free; the projection output ties to the stage governor so
residual adds stay consistent. Pointwise convs run through the fused Pallas
quantize->matmul kernel (`pwconv`), which dominates MobileNet compute.
"""

from __future__ import annotations

import jax.numpy as jnp

from .common import (Builder, Model, channel_mask, cmax_of, conv2d, dense,
                     dwconv2d, pwconv, batchnorm, global_avg_pool,
                     make_bn_params, make_conv_param)


def build_mobilenet_v1(name: str, num_classes: int, image_hw: int,
                       stem_base: int, block_cfg) -> Model:
    """block_cfg: list of (out_base, stride) for the 13 dw/pw pairs."""
    b = Builder()
    hw = image_hw

    stem_cmax = cmax_of(stem_base)
    stem_idx = b.add_layer(name="stem", kind="conv", ksize=3, stride=1,
                           in_base=3, out_base=stem_base, cmax_in=3,
                           cmax_out=stem_cmax, out_h=hw, out_w=hw)
    stem_w = make_conv_param(b, "stem.w", 3, 3, stem_cmax)
    stem_g, stem_bb = make_bn_params(b, "stem.bn", stem_cmax)

    pairs = []
    in_tie, in_base, in_cmax = stem_idx, stem_base, stem_cmax
    for i, (out_base, stride) in enumerate(block_cfg):
        if stride == 2:
            hw //= 2
        out_cmax = cmax_of(out_base)
        pfx = f"b{i}"
        dw_idx = b.add_layer(name=f"{pfx}.dw", kind="dwconv", ksize=3,
                             stride=stride, in_base=in_base, out_base=in_base,
                             cmax_in=in_cmax, cmax_out=in_cmax, out_h=hw,
                             out_w=hw, width_tie=in_tie)
        dw_w = b.add_param(f"{pfx}.dw.w", (3, 3, 1, in_cmax), "he", 9, decay=True)
        dw_g, dw_b = make_bn_params(b, f"{pfx}.dw.bn", in_cmax)
        pw_idx = b.add_layer(name=f"{pfx}.pw", kind="pwconv", ksize=1, stride=1,
                             in_base=in_base, out_base=out_base, cmax_in=in_cmax,
                             cmax_out=out_cmax, out_h=hw, out_w=hw)
        pw_w = b.add_param(f"{pfx}.pw.w", (in_cmax, out_cmax), "he", in_cmax,
                           decay=True)
        pw_g, pw_b = make_bn_params(b, f"{pfx}.pw.bn", out_cmax)
        pairs.append(dict(dw=(dw_idx, dw_w, dw_g, dw_b),
                          pw=(pw_idx, pw_w, pw_g, pw_b),
                          in_cmax=in_cmax, out_cmax=out_cmax))
        in_tie, in_base, in_cmax = pw_idx, out_base, out_cmax

    fc_idx = b.add_layer(name="fc", kind="fc", ksize=1, stride=1,
                         in_base=in_base, out_base=num_classes, cmax_in=in_cmax,
                         cmax_out=num_classes, out_h=1, out_w=1,
                         width_tie=in_tie, width_fixed=True)
    fc_w = b.add_param("fc.w", (in_cmax, num_classes), "he", in_cmax, decay=True)
    fc_b = b.add_param("fc.b", (num_classes,), "zeros", 1, decay=False)

    layers, params_spec = b.layers, b.params

    def apply(params, x, bits, widths, quant=True):
        relu = jnp.maximum
        m = channel_mask(widths, layers[stem_idx].width_tie, stem_cmax)
        ones3 = jnp.ones((3,), dtype=jnp.float32)
        h = conv2d(params, x, stem_w, layers[stem_idx], bits, widths, quant,
                   ones3, m)
        h = relu(batchnorm(params, h, stem_g, stem_bb, m), 0.0)
        for pr in pairs:
            dw_idx_, dw_w_, dw_g_, dw_b_ = pr["dw"]
            pw_idx_, pw_w_, pw_g_, pw_b_ = pr["pw"]
            m_in = channel_mask(widths, layers[dw_idx_].width_tie, pr["in_cmax"])
            m_out = channel_mask(widths, layers[pw_idx_].width_tie, pr["out_cmax"])
            h = dwconv2d(params, h, dw_w_, layers[dw_idx_], bits, widths, quant, m_in)
            h = relu(batchnorm(params, h, dw_g_, dw_b_, m_in), 0.0)
            h = pwconv(params, h, pw_w_, layers[pw_idx_], bits, widths, quant,
                       m_in, m_out)
            h = relu(batchnorm(params, h, pw_g_, pw_b_, m_out), 0.0)
        pooled = global_avg_pool(h)
        return dense(params, pooled, fc_w, fc_b, layers[fc_idx], bits, quant)

    return Model(name=name, num_classes=num_classes, image_hw=image_hw,
                 params=params_spec, layers=layers, apply=apply)


def build_mobilenet_v2(name: str, num_classes: int, image_hw: int,
                       stem_base: int, block_cfg, head_base: int) -> Model:
    """block_cfg: list of (expand_ratio, out_base, stride, n_repeat)."""
    b = Builder()
    hw = image_hw

    stem_cmax = cmax_of(stem_base)
    stem_idx = b.add_layer(name="stem", kind="conv", ksize=3, stride=1,
                           in_base=3, out_base=stem_base, cmax_in=3,
                           cmax_out=stem_cmax, out_h=hw, out_w=hw)
    stem_w = make_conv_param(b, "stem.w", 3, 3, stem_cmax)
    stem_g, stem_bb = make_bn_params(b, "stem.bn", stem_cmax)

    blocks = []
    in_tie, in_base, in_cmax = stem_idx, stem_base, stem_cmax
    bi = 0
    for (t, out_base, stride0, n) in block_cfg:
        for r in range(n):
            stride = stride0 if r == 0 else 1
            if stride == 2:
                hw //= 2
            out_cmax = cmax_of(out_base)
            pfx = f"b{bi}"
            bi += 1
            mid_base = in_base * t
            mid_cmax = cmax_of(mid_base)
            exp = None
            if t != 1:
                exp_idx = b.add_layer(name=f"{pfx}.expand", kind="pwconv",
                                      ksize=1, stride=1, in_base=in_base,
                                      out_base=mid_base, cmax_in=in_cmax,
                                      cmax_out=mid_cmax, out_h=hw * stride,
                                      out_w=hw * stride)
                exp_w = b.add_param(f"{pfx}.expand.w", (in_cmax, mid_cmax),
                                    "he", in_cmax, decay=True)
                exp_g, exp_b = make_bn_params(b, f"{pfx}.expand.bn", mid_cmax)
                exp = (exp_idx, exp_w, exp_g, exp_b)
                dw_tie = exp_idx
            else:
                mid_base, mid_cmax = in_base, in_cmax
                dw_tie = in_tie
            dw_idx = b.add_layer(name=f"{pfx}.dw", kind="dwconv", ksize=3,
                                 stride=stride, in_base=mid_base,
                                 out_base=mid_base, cmax_in=mid_cmax,
                                 cmax_out=mid_cmax, out_h=hw, out_w=hw,
                                 width_tie=dw_tie)
            dw_w = b.add_param(f"{pfx}.dw.w", (3, 3, 1, mid_cmax), "he", 9,
                               decay=True)
            dw_g, dw_b = make_bn_params(b, f"{pfx}.dw.bn", mid_cmax)
            residual = (stride == 1 and in_base == out_base)
            if residual:
                proj_idx = b.add_layer(name=f"{pfx}.project", kind="pwconv",
                                       ksize=1, stride=1, in_base=mid_base,
                                       out_base=out_base, cmax_in=mid_cmax,
                                       cmax_out=out_cmax, out_h=hw, out_w=hw,
                                       width_tie=in_tie)
                governor = in_tie
            else:
                proj_idx = b.add_layer(name=f"{pfx}.project", kind="pwconv",
                                       ksize=1, stride=1, in_base=mid_base,
                                       out_base=out_base, cmax_in=mid_cmax,
                                       cmax_out=out_cmax, out_h=hw, out_w=hw)
                governor = proj_idx
            proj_w = b.add_param(f"{pfx}.project.w", (mid_cmax, out_cmax),
                                 "he", mid_cmax, decay=True)
            proj_g, proj_b = make_bn_params(b, f"{pfx}.project.bn", out_cmax)
            blocks.append(dict(exp=exp, dw=(dw_idx, dw_w, dw_g, dw_b),
                               proj=(proj_idx, proj_w, proj_g, proj_b),
                               residual=residual, mid_cmax=mid_cmax,
                               out_cmax=out_cmax, in_cmax=in_cmax))
            in_tie, in_base, in_cmax = governor, out_base, out_cmax

    head_cmax = cmax_of(head_base)
    head_idx = b.add_layer(name="head", kind="pwconv", ksize=1, stride=1,
                           in_base=in_base, out_base=head_base, cmax_in=in_cmax,
                           cmax_out=head_cmax, out_h=hw, out_w=hw)
    head_w = b.add_param("head.w", (in_cmax, head_cmax), "he", in_cmax, decay=True)
    head_g, head_bb = make_bn_params(b, "head.bn", head_cmax)

    fc_idx = b.add_layer(name="fc", kind="fc", ksize=1, stride=1,
                         in_base=head_base, out_base=num_classes,
                         cmax_in=head_cmax, cmax_out=num_classes, out_h=1,
                         out_w=1, width_tie=head_idx, width_fixed=True)
    fc_w = b.add_param("fc.w", (head_cmax, num_classes), "he", head_cmax, decay=True)
    fc_b = b.add_param("fc.b", (num_classes,), "zeros", 1, decay=False)

    layers, params_spec = b.layers, b.params

    def apply(params, x, bits, widths, quant=True):
        relu6 = lambda v: jnp.clip(v, 0.0, 6.0)
        m = channel_mask(widths, layers[stem_idx].width_tie, stem_cmax)
        ones3 = jnp.ones((3,), dtype=jnp.float32)
        h = conv2d(params, x, stem_w, layers[stem_idx], bits, widths, quant,
                   ones3, m)
        h = relu6(batchnorm(params, h, stem_g, stem_bb, m))
        cur_mask = m
        for blk in blocks:
            inp = h
            in_mask = cur_mask
            if blk["exp"] is not None:
                exp_idx_, exp_w_, exp_g_, exp_b_ = blk["exp"]
                m_mid = channel_mask(widths, layers[exp_idx_].width_tie,
                                     blk["mid_cmax"])
                h = pwconv(params, h, exp_w_, layers[exp_idx_], bits, widths,
                           quant, in_mask, m_mid)
                h = relu6(batchnorm(params, h, exp_g_, exp_b_, m_mid))
            else:
                m_mid = in_mask
            dw_idx_, dw_w_, dw_g_, dw_b_ = blk["dw"]
            h = dwconv2d(params, h, dw_w_, layers[dw_idx_], bits, widths, quant,
                         m_mid)
            h = relu6(batchnorm(params, h, dw_g_, dw_b_, m_mid))
            proj_idx_, proj_w_, proj_g_, proj_b_ = blk["proj"]
            m_out = channel_mask(widths, layers[proj_idx_].width_tie,
                                 blk["out_cmax"])
            h = pwconv(params, h, proj_w_, layers[proj_idx_], bits, widths,
                       quant, m_mid, m_out)
            h = batchnorm(params, h, proj_g_, proj_b_, m_out)
            if blk["residual"]:
                h = h + inp
            cur_mask = m_out
        m_head = channel_mask(widths, layers[head_idx].width_tie, head_cmax)
        h = pwconv(params, h, head_w, layers[head_idx], bits, widths, quant,
                   cur_mask, m_head)
        h = relu6(batchnorm(params, h, head_g, head_bb, m_head))
        pooled = global_avg_pool(h)
        return dense(params, pooled, fc_w, fc_b, layers[fc_idx], bits, quant)

    return Model(name=name, num_classes=num_classes, image_hw=image_hw,
                 params=params_spec, layers=layers, apply=apply)
