"""Model registry: proxy-scale configurations of the paper's architectures.

The paper evaluates ResNet-18/50 + MobileNetV2 on ImageNet, ResNet-18 +
MobileNetV1 on CIFAR-100 and ResNet-20 on CIFAR-10. This testbed is a single
CPU core, so every architecture is instantiated at proxy scale (16x16 inputs,
reduced base widths) with the LAYER STRUCTURE preserved — layer count, stage
layout, depthwise/pointwise/bottleneck/residual topology, which is what makes
the (bits, widths) search space heterogeneous (DESIGN.md §2).

Datasets map to class counts of the synthetic generators in rust `data/`:
cifar10-proxy=10, cifar100-proxy=20, imagenet-proxy=30 classes.
"""

from __future__ import annotations

from .common import Model
from .mobilenet import build_mobilenet_v1, build_mobilenet_v2
from .resnet import build_resnet_basic, build_resnet_bottleneck

IMAGE_HW = 16


def resnet20(num_classes: int = 10) -> Model:
    # 3 stages x 3 basic blocks -> 19 convs + fc = 20+shortcut quantized layers.
    return build_resnet_basic("resnet20", num_classes, IMAGE_HW,
                              stage_bases=(8, 16, 32), blocks_per_stage=(3, 3, 3))


def resnet18(num_classes: int = 20) -> Model:
    # 4 stages x 2 basic blocks -> 17 convs + fc (paper's vector: 17 entries).
    return build_resnet_basic("resnet18", num_classes, IMAGE_HW,
                              stage_bases=(8, 16, 24, 32),
                              blocks_per_stage=(2, 2, 2, 2))


def resnet50s(num_classes: int = 30) -> Model:
    # Bottleneck ResNet, slimmed: 4 stages x 2 blocks x 3 convs + shortcuts.
    return build_resnet_bottleneck("resnet50s", num_classes, IMAGE_HW,
                                   stage_bases=(8, 12, 16, 24),
                                   blocks_per_stage=(2, 2, 2, 2), expand=2)


def mobilenetv1(num_classes: int = 20) -> Model:
    # Standard 13-pair MobileNetV1 layout, narrowed.
    cfg = [(12, 1), (16, 2), (16, 1), (24, 2), (24, 1),
           (32, 2), (32, 1), (32, 1), (32, 1), (32, 1), (32, 1),
           (48, 2), (48, 1)]
    return build_mobilenet_v1("mobilenetv1", num_classes, IMAGE_HW,
                              stem_base=8, block_cfg=cfg)


def mobilenetv2(num_classes: int = 30) -> Model:
    # Inverted-residual layout (t, c, s, n), narrowed + shortened.
    cfg = [(1, 8, 1, 1), (4, 12, 2, 2), (4, 16, 2, 2), (4, 24, 2, 1)]
    return build_mobilenet_v2("mobilenetv2", num_classes, IMAGE_HW,
                              stem_base=8, block_cfg=cfg, head_base=48)


BUILDERS = {
    "resnet20": resnet20,
    "resnet18": resnet18,
    "resnet50s": resnet50s,
    "mobilenetv1": mobilenetv1,
    "mobilenetv2": mobilenetv2,
}

# (model, dataset) pairs exported by `make artifacts` — one per Table II block.
EXPORTS = [
    ("resnet20", "cifar10", 10),
    ("resnet18", "cifar100", 20),
    ("mobilenetv1", "cifar100", 20),
    ("resnet18", "imagenet", 30),
    ("mobilenetv2", "imagenet", 30),
    ("resnet50s", "imagenet", 30),
]


def build(model: str, num_classes: int) -> Model:
    return BUILDERS[model](num_classes)
