"""L2 model-construction framework: width-masked, runtime-quantized layers.

Design (DESIGN.md §6.1): ONE lowered HLO artifact must serve the entire
(bit-width x layer-width) search space, so neither may change tensor shapes:

  * bit-widths enter as a runtime `f32[L]` input; layer `l` fake-quantizes its
    weights AND input activations with `bits[l]` (paper §III-A: same bit-width
    for weights and input activations of a layer);
  * layer widths enter as a runtime `f32[L]` vector of ACTIVE CHANNEL COUNTS.
    Every channel dimension is statically sized at `cmax = ceil(1.25 * base)`
    (1.25 = max width multiplier in S) and a mask `iota(cmax) < widths[l]`
    zeroes inactive channels. Structural ties (residual adds, depthwise
    channels) are recorded in the layer metadata and resolved by the Rust
    coordinator, which always sends a fully-consistent widths vector.

`quant=False` builds the pure-FP graph (no Pallas calls, no rounding): used by
the Hessian-trace program, which needs forward-mode AD that `custom_vjp`
straight-through estimators cannot provide — and matches the paper, where
sensitivity analysis runs on the full-precision pretrained model.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..qat import fake_quant_ste, qmatmul_ste

WIDTH_MULTS = [0.75, 0.875, 1.0, 1.125, 1.25]
MAX_MULT = 1.25


def cmax_of(base: int) -> int:
    return int(math.ceil(MAX_MULT * base))


@dataclass
class ParamSpec:
    """One parameter tensor: creation-ordered; Rust initializes from this."""
    name: str
    shape: tuple
    init: str      # 'he' | 'zeros' | 'ones'
    fan_in: int    # for 'he' init: std = sqrt(2 / fan_in)
    decay: bool    # apply weight decay (conv/fc kernels only)


@dataclass
class LayerMeta:
    """One *quantized* layer: drives the hw model + search space in Rust."""
    index: int
    name: str
    kind: str           # 'conv' | 'dwconv' | 'pwconv' | 'fc'
    ksize: int
    stride: int
    in_base: int        # base (mult=1.0) input channel count
    out_base: int       # base output channel count
    cmax_in: int
    cmax_out: int
    out_h: int
    out_w: int
    width_tie: int      # layer index whose WIDTH entry governs this OUTPUT
    bits_tie: int       # layer index whose BITS entry this layer uses
    width_fixed: bool   # output width not searchable (e.g. fc -> classes)
    bits_free: bool     # own bit-width search dimension (False: bits_tie'd)


class Builder:
    """Accumulates ParamSpecs / LayerMetas while the apply() closure is built."""

    def __init__(self):
        self.params: List[ParamSpec] = []
        self.layers: List[LayerMeta] = []

    def add_param(self, name, shape, init, fan_in, decay) -> int:
        self.params.append(ParamSpec(name, tuple(int(s) for s in shape), init,
                                     int(fan_in), decay))
        return len(self.params) - 1

    def add_layer(self, **kw) -> int:
        idx = len(self.layers)
        kw.setdefault("width_tie", idx)
        kw.setdefault("bits_tie", idx)
        kw.setdefault("width_fixed", False)
        kw.setdefault("bits_free", True)
        self.layers.append(LayerMeta(index=idx, **kw))
        return idx


def channel_mask(widths: jax.Array, layer_idx: int, cmax: int) -> jax.Array:
    """f32[cmax] mask of active channels for layer `layer_idx`'s output."""
    iota = lax.broadcasted_iota(jnp.float32, (cmax,), 0)
    return (iota < widths[layer_idx]).astype(jnp.float32)


def maybe_quant(x: jax.Array, bits: jax.Array, layer_idx: int, quant: bool) -> jax.Array:
    """Fake-quantize through the Pallas STE kernel when building the QAT graph."""
    if not quant:
        return x
    return fake_quant_ste(x, lax.dynamic_slice_in_dim(bits, layer_idx, 1))


# ---------------------------------------------------------------------------
# Layer apply helpers. All activations are NHWC; conv kernels are HWIO.
# ---------------------------------------------------------------------------

def conv2d(params, x, w_idx, meta: LayerMeta, bits, widths, quant, mask_in,
           mask_out):
    """Standard conv: quantize input activations + masked weights, convolve,
    re-mask output channels."""
    w = params[w_idx]
    w = w * mask_in[None, None, :, None] * mask_out[None, None, None, :]
    li = meta.bits_tie
    xq = maybe_quant(x, bits, li, quant)
    wq = maybe_quant(w, bits, li, quant)
    y = lax.conv_general_dilated(
        xq, wq, window_strides=(meta.stride, meta.stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y * mask_out[None, None, None, :]


def dwconv2d(params, x, w_idx, meta: LayerMeta, bits, widths, quant, mask):
    """Depthwise conv: channel set identical on input/output (mask shared)."""
    w = params[w_idx]  # (k, k, 1, C)
    w = w * mask[None, None, None, :]
    li = meta.bits_tie
    xq = maybe_quant(x, bits, li, quant)
    wq = maybe_quant(w, bits, li, quant)
    c = w.shape[-1]
    y = lax.conv_general_dilated(
        xq, wq, window_strides=(meta.stride, meta.stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=c)
    return y * mask[None, None, None, :]


def pwconv(params, x, w_idx, meta: LayerMeta, bits, widths, quant, mask_in,
           mask_out):
    """Pointwise (1x1) conv as the fused Pallas quantize->matmul kernel —
    the matmul-shaped hot path of the MobileNets."""
    n, h, wd, c = x.shape
    w = params[w_idx]  # (C_in, C_out)
    w = w * mask_in[:, None] * mask_out[None, :]
    xm = x.reshape(n * h * wd, c)
    li = meta.bits_tie
    if quant:
        b = bits[li]
        y = qmatmul_ste(xm, w, b, b)
    else:
        y = xm @ w
    y = y.reshape(n, h, wd, w.shape[1])
    return y * mask_out[None, None, None, :]


def dense(params, x, w_idx, b_idx, meta: LayerMeta, bits, quant):
    """Final classifier head via the fused Pallas kernel."""
    w = params[w_idx]
    li = meta.bits_tie
    if quant:
        b = bits[li]
        y = qmatmul_ste(x, w, b, b)
    else:
        y = x @ w
    return y + params[b_idx][None, :]


def batchnorm(params, x, g_idx, b_idx, mask):
    """Batch-stat normalization (no running stats — proxy-training regime;
    the evaluator also uses batch stats, documented in DESIGN.md). Masked
    channels stay exactly zero: normalize, affine, re-mask."""
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    y = (x - mean) * lax.rsqrt(var + 1e-5)
    y = y * params[g_idx][None, None, None, :] + params[b_idx][None, None, None, :]
    return y * mask[None, None, None, :]


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def make_conv_param(b: Builder, name: str, k: int, cin: int, cout: int) -> int:
    return b.add_param(name, (k, k, cin, cout), "he", k * k * cin, decay=True)


def make_bn_params(b: Builder, name: str, c: int):
    g = b.add_param(f"{name}.gamma", (c,), "ones", c, decay=False)
    bb = b.add_param(f"{name}.beta", (c,), "zeros", c, decay=False)
    return g, bb


@dataclass
class Model:
    """A fully-built model: parameter specs, quantized-layer metadata, and the
    apply closure `(params, x, bits, widths, quant) -> logits`."""
    name: str
    num_classes: int
    image_hw: int
    params: List[ParamSpec]
    layers: List[LayerMeta]
    apply: Callable

    @property
    def num_layers(self) -> int:
        return len(self.layers)
