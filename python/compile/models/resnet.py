"""ResNet family (CIFAR-style) with searchable per-layer bit-widths and widths.

Width-tie scheme (resolved by the Rust coordinator; recorded in LayerMeta):
  * each stage has a governing width dimension — the stem for stage 1, the
    residual-branch output conv of the first block for later stages;
  * every tensor that participates in a residual add (block output convs,
    downsample shortcuts) ties its output width to the stage governor;
  * the inner conv of every block is a FREE width dimension (this is where the
    paper's "widen a layer while quantizing it harder" trade-off lives).

Shortcut 1x1 convs are real quantized layers (they carry weights, count toward
model size and latency) but are not independent search dimensions: bits tie to
the block's output conv, width ties to the stage governor (bits_free=False).
"""

from __future__ import annotations

import jax.numpy as jnp

from .common import (Builder, Model, channel_mask, cmax_of, conv2d, dense,
                     batchnorm, global_avg_pool, make_bn_params,
                     make_conv_param)


def _bn(b, name, c):
    return make_bn_params(b, name, c)


def build_resnet_basic(name: str, num_classes: int, image_hw: int,
                       stage_bases, blocks_per_stage) -> Model:
    """Basic-block ResNet (ResNet-20 / ResNet-18 shapes)."""
    b = Builder()
    hw = image_hw

    stem_base = stage_bases[0]
    stem_cmax = cmax_of(stem_base)
    stem_idx = b.add_layer(name="stem", kind="conv", ksize=3, stride=1,
                           in_base=3, out_base=stem_base, cmax_in=3,
                           cmax_out=stem_cmax, out_h=hw, out_w=hw)
    stem_w = make_conv_param(b, "stem.w", 3, 3, stem_cmax)
    stem_g, stem_bb = _bn(b, "stem.bn", stem_cmax)

    blocks = []
    in_tie, in_base, in_cmax = stem_idx, stem_base, stem_cmax
    for s, (base, nblocks) in enumerate(zip(stage_bases, blocks_per_stage)):
        cmax = cmax_of(base)
        for i in range(nblocks):
            stride = 2 if (s > 0 and i == 0) else 1
            if stride == 2:
                hw //= 2
            pfx = f"s{s}b{i}"
            # Inner conv: free width dimension.
            c1_idx = b.add_layer(name=f"{pfx}.conv1", kind="conv", ksize=3,
                                 stride=stride, in_base=in_base, out_base=base,
                                 cmax_in=in_cmax, cmax_out=cmax, out_h=hw, out_w=hw)
            c1_w = make_conv_param(b, f"{pfx}.conv1.w", 3, in_cmax, cmax)
            c1_g, c1_b = _bn(b, f"{pfx}.conv1.bn", cmax)
            # Output conv: first block of a widening stage becomes governor.
            needs_proj = (in_base != base) or (stride != 1)
            if i == 0 and s > 0:
                c2_idx = b.add_layer(name=f"{pfx}.conv2", kind="conv", ksize=3,
                                     stride=1, in_base=base, out_base=base,
                                     cmax_in=cmax, cmax_out=cmax, out_h=hw, out_w=hw)
                governor = c2_idx
            else:
                c2_idx = b.add_layer(name=f"{pfx}.conv2", kind="conv", ksize=3,
                                     stride=1, in_base=base, out_base=base,
                                     cmax_in=cmax, cmax_out=cmax, out_h=hw,
                                     out_w=hw, width_tie=in_tie if not needs_proj else in_tie)
                governor = in_tie
            c2_w = make_conv_param(b, f"{pfx}.conv2.w", 3, cmax, cmax)
            c2_g, c2_b = _bn(b, f"{pfx}.conv2.bn", cmax)

            sc = None
            if needs_proj:
                sc_idx = b.add_layer(name=f"{pfx}.down", kind="conv", ksize=1,
                                     stride=stride, in_base=in_base, out_base=base,
                                     cmax_in=in_cmax, cmax_out=cmax, out_h=hw,
                                     out_w=hw, width_tie=governor,
                                     bits_tie=c2_idx, bits_free=False)
                sc_w = make_conv_param(b, f"{pfx}.down.w", 1, in_cmax, cmax)
                sc_g, sc_b = _bn(b, f"{pfx}.down.bn", cmax)
                sc = (sc_idx, sc_w, sc_g, sc_b)

            blocks.append(dict(c1=(c1_idx, c1_w, c1_g, c1_b),
                               c2=(c2_idx, c2_w, c2_g, c2_b), sc=sc,
                               in_tie=in_tie, in_cmax=in_cmax,
                               governor=governor, cmax=cmax))
            in_tie, in_base, in_cmax = governor, base, cmax

    fc_idx = b.add_layer(name="fc", kind="fc", ksize=1, stride=1,
                         in_base=stage_bases[-1], out_base=num_classes,
                         cmax_in=in_cmax, cmax_out=num_classes, out_h=1, out_w=1,
                         width_tie=in_tie, width_fixed=True)
    fc_w = b.add_param("fc.w", (in_cmax, num_classes), "he", in_cmax, decay=True)
    fc_b = b.add_param("fc.b", (num_classes,), "zeros", 1, decay=False)

    layers = b.layers
    params_spec = b.params

    def apply(params, x, bits, widths, quant=True):
        relu = jnp.maximum
        m_stem = channel_mask(widths, layers[stem_idx].width_tie, stem_cmax)
        ones3 = jnp.ones((3,), dtype=jnp.float32)
        h = conv2d(params, x, stem_w, layers[stem_idx], bits, widths, quant,
                   ones3, m_stem)
        h = relu(batchnorm(params, h, stem_g, stem_bb, m_stem), 0.0)
        cur, cur_mask = h, m_stem
        for blk in blocks:
            c1_idx_, c1_w_, c1_g_, c1_b_ = blk["c1"]
            c2_idx_, c2_w_, c2_g_, c2_b_ = blk["c2"]
            m_mid = channel_mask(widths, layers[c1_idx_].width_tie, blk["cmax"])
            m_out = channel_mask(widths, layers[c2_idx_].width_tie, blk["cmax"])
            t = conv2d(params, cur, c1_w_, layers[c1_idx_], bits, widths, quant,
                       cur_mask, m_mid)
            t = relu(batchnorm(params, t, c1_g_, c1_b_, m_mid), 0.0)
            t = conv2d(params, t, c2_w_, layers[c2_idx_], bits, widths, quant,
                       m_mid, m_out)
            t = batchnorm(params, t, c2_g_, c2_b_, m_out)
            if blk["sc"] is not None:
                sc_idx_, sc_w_, sc_g_, sc_b_ = blk["sc"]
                s = conv2d(params, cur, sc_w_, layers[sc_idx_], bits, widths,
                           quant, cur_mask, m_out)
                s = batchnorm(params, s, sc_g_, sc_b_, m_out)
            else:
                s = cur
            cur = relu(t + s, 0.0)
            cur_mask = m_out
        pooled = global_avg_pool(cur)
        return dense(params, pooled, fc_w, fc_b, layers[fc_idx], bits, quant)

    return Model(name=name, num_classes=num_classes, image_hw=image_hw,
                 params=params_spec, layers=layers, apply=apply)


def build_resnet_bottleneck(name: str, num_classes: int, image_hw: int,
                            stage_bases, blocks_per_stage,
                            expand: int = 2) -> Model:
    """Bottleneck ResNet (ResNet-50-slim). Inner 1x1 reduce and 3x3 convs are
    free width dims; the 1x1 expand conv ties to the stage governor."""
    b = Builder()
    hw = image_hw

    stem_base = stage_bases[0]
    stem_cmax = cmax_of(stem_base)
    stem_idx = b.add_layer(name="stem", kind="conv", ksize=3, stride=1,
                           in_base=3, out_base=stem_base, cmax_in=3,
                           cmax_out=stem_cmax, out_h=hw, out_w=hw)
    stem_w = make_conv_param(b, "stem.w", 3, 3, stem_cmax)
    stem_g, stem_bb = _bn(b, "stem.bn", stem_cmax)

    blocks = []
    in_tie, in_base, in_cmax = stem_idx, stem_base, stem_cmax
    for s, (base, nblocks) in enumerate(zip(stage_bases, blocks_per_stage)):
        out_base = base * expand
        cmax_i = cmax_of(base)
        cmax_o = cmax_of(out_base)
        for i in range(nblocks):
            stride = 2 if (s > 0 and i == 0) else 1
            if stride == 2:
                hw //= 2
            pfx = f"s{s}b{i}"
            c1_idx = b.add_layer(name=f"{pfx}.reduce", kind="conv", ksize=1,
                                 stride=1, in_base=in_base, out_base=base,
                                 cmax_in=in_cmax, cmax_out=cmax_i,
                                 out_h=hw * stride, out_w=hw * stride)
            c1_w = make_conv_param(b, f"{pfx}.reduce.w", 1, in_cmax, cmax_i)
            c1_g, c1_b = _bn(b, f"{pfx}.reduce.bn", cmax_i)
            c2_idx = b.add_layer(name=f"{pfx}.conv3x3", kind="conv", ksize=3,
                                 stride=stride, in_base=base, out_base=base,
                                 cmax_in=cmax_i, cmax_out=cmax_i, out_h=hw,
                                 out_w=hw, width_tie=c1_idx, bits_free=True)
            c2_w = make_conv_param(b, f"{pfx}.conv3x3.w", 3, cmax_i, cmax_i)
            c2_g, c2_b = _bn(b, f"{pfx}.conv3x3.bn", cmax_i)
            needs_proj = (i == 0)
            if i == 0:
                c3_idx = b.add_layer(name=f"{pfx}.expand", kind="conv", ksize=1,
                                     stride=1, in_base=base, out_base=out_base,
                                     cmax_in=cmax_i, cmax_out=cmax_o, out_h=hw,
                                     out_w=hw)
                governor = c3_idx
            else:
                c3_idx = b.add_layer(name=f"{pfx}.expand", kind="conv", ksize=1,
                                     stride=1, in_base=base, out_base=out_base,
                                     cmax_in=cmax_i, cmax_out=cmax_o, out_h=hw,
                                     out_w=hw, width_tie=in_tie)
                governor = in_tie
            c3_w = make_conv_param(b, f"{pfx}.expand.w", 1, cmax_i, cmax_o)
            c3_g, c3_b = _bn(b, f"{pfx}.expand.bn", cmax_o)

            sc = None
            if needs_proj:
                sc_idx = b.add_layer(name=f"{pfx}.down", kind="conv", ksize=1,
                                     stride=stride, in_base=in_base,
                                     out_base=out_base, cmax_in=in_cmax,
                                     cmax_out=cmax_o, out_h=hw, out_w=hw,
                                     width_tie=governor, bits_tie=c3_idx,
                                     bits_free=False)
                sc_w = make_conv_param(b, f"{pfx}.down.w", 1, in_cmax, cmax_o)
                sc_g, sc_b = _bn(b, f"{pfx}.down.bn", cmax_o)
                sc = (sc_idx, sc_w, sc_g, sc_b)

            blocks.append(dict(c1=(c1_idx, c1_w, c1_g, c1_b),
                               c2=(c2_idx, c2_w, c2_g, c2_b),
                               c3=(c3_idx, c3_w, c3_g, c3_b), sc=sc,
                               cmax_i=cmax_i, cmax_o=cmax_o, governor=governor))
            in_tie, in_base, in_cmax = governor, out_base, cmax_o

    fc_idx = b.add_layer(name="fc", kind="fc", ksize=1, stride=1,
                         in_base=in_base, out_base=num_classes, cmax_in=in_cmax,
                         cmax_out=num_classes, out_h=1, out_w=1,
                         width_tie=in_tie, width_fixed=True)
    fc_w = b.add_param("fc.w", (in_cmax, num_classes), "he", in_cmax, decay=True)
    fc_b = b.add_param("fc.b", (num_classes,), "zeros", 1, decay=False)

    layers = b.layers
    params_spec = b.params

    def apply(params, x, bits, widths, quant=True):
        relu = jnp.maximum
        m_stem = channel_mask(widths, layers[stem_idx].width_tie, stem_cmax)
        ones3 = jnp.ones((3,), dtype=jnp.float32)
        h = conv2d(params, x, stem_w, layers[stem_idx], bits, widths, quant,
                   ones3, m_stem)
        h = relu(batchnorm(params, h, stem_g, stem_bb, m_stem), 0.0)
        cur, cur_mask = h, m_stem
        for blk in blocks:
            c1_idx_, c1_w_, c1_g_, c1_b_ = blk["c1"]
            c2_idx_, c2_w_, c2_g_, c2_b_ = blk["c2"]
            c3_idx_, c3_w_, c3_g_, c3_b_ = blk["c3"]
            m_i = channel_mask(widths, layers[c1_idx_].width_tie, blk["cmax_i"])
            m_o = channel_mask(widths, layers[c3_idx_].width_tie, blk["cmax_o"])
            t = conv2d(params, cur, c1_w_, layers[c1_idx_], bits, widths, quant,
                       cur_mask, m_i)
            t = relu(batchnorm(params, t, c1_g_, c1_b_, m_i), 0.0)
            t = conv2d(params, t, c2_w_, layers[c2_idx_], bits, widths, quant,
                       m_i, m_i)
            t = relu(batchnorm(params, t, c2_g_, c2_b_, m_i), 0.0)
            t = conv2d(params, t, c3_w_, layers[c3_idx_], bits, widths, quant,
                       m_i, m_o)
            t = batchnorm(params, t, c3_g_, c3_b_, m_o)
            if blk["sc"] is not None:
                sc_idx_, sc_w_, sc_g_, sc_b_ = blk["sc"]
                s = conv2d(params, cur, sc_w_, layers[sc_idx_], bits, widths,
                           quant, cur_mask, m_o)
                s = batchnorm(params, s, sc_g_, sc_b_, m_o)
            else:
                s = cur
            cur = relu(t + s, 0.0)
            cur_mask = m_o
        pooled = global_avg_pool(cur)
        return dense(params, pooled, fc_w, fc_b, layers[fc_idx], bits, quant)

    return Model(name=name, num_classes=num_classes, image_hw=image_hw,
                 params=params_spec, layers=layers, apply=apply)
