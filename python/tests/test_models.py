"""L2 model correctness: shapes, masking invariants, training signal.

The width-masking contract is what lets ONE artifact serve the whole search
space, so it gets the heaviest testing: masked channels must be exactly zero,
active-channel outputs must be invariant to the existence of masked slots,
and every (model, width-config) pair must produce finite logits + gradients.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import registry
from compile.models.common import cmax_of, WIDTH_MULTS
from compile import train as T

SMALL = ["resnet20", "resnet18", "mobilenetv1", "mobilenetv2", "resnet50s"]


def init_params(model, seed=0):
    rng = np.random.RandomState(seed)
    out = []
    for p in model.params:
        if p.init == "he":
            out.append(jnp.array(rng.randn(*p.shape).astype(np.float32)
                                 * np.sqrt(2.0 / p.fan_in)))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, jnp.float32))
        else:
            out.append(jnp.zeros(p.shape, jnp.float32))
    return out


def base_widths(model, mult=1.0):
    return jnp.array([round(l.out_base * mult) for l in model.layers],
                     jnp.float32)


def batch(model, bs=8, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.array(rng.randn(bs, model.image_hw, model.image_hw, 3)
                  .astype(np.float32))
    y = jnp.array(rng.randint(0, model.num_classes, bs).astype(np.int32))
    return x, y


@pytest.mark.parametrize("name", SMALL)
def test_forward_shape_and_finite(name):
    m = registry.BUILDERS[name]()
    params = init_params(m)
    x, _ = batch(m)
    bits = jnp.full((m.num_layers,), 8.0)
    logits = m.apply(params, x, bits, base_widths(m), quant=True)
    assert logits.shape == (8, m.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ["resnet20", "mobilenetv1"])
@pytest.mark.parametrize("mult", WIDTH_MULTS)
def test_all_width_multipliers(name, mult):
    m = registry.BUILDERS[name]()
    params = init_params(m)
    x, _ = batch(m)
    bits = jnp.full((m.num_layers,), 6.0)
    logits = m.apply(params, x, bits, base_widths(m, mult), quant=True)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_quant_fp_agree_at_16_bits():
    """quant=True at 16 bits ~ the FP graph (hessian program consistency)."""
    m = registry.resnet20()
    params = init_params(m)
    x, _ = batch(m)
    bits = jnp.full((m.num_layers,), 16.0)
    w = base_widths(m)
    lq = m.apply(params, x, bits, w, quant=True)
    lf = m.apply(params, x, bits, w, quant=False)
    np.testing.assert_allclose(np.array(lq), np.array(lf), rtol=0.05, atol=0.05)


def test_masked_channels_are_inert():
    """Garbage written into weight channels beyond the active count must not
    change the logits — the invariant that lets one artifact serve all
    widths. Conv kernels are matched to layers by name prefix."""
    m = registry.resnet20()
    params = init_params(m)
    x, _ = batch(m)
    bits = jnp.full((m.num_layers,), 8.0)
    mult = 0.75
    w = base_widths(m, mult)
    logits1 = m.apply(params, x, bits, w, quant=True)

    active_by_layer = {}
    for l in m.layers:
        gov = m.layers[l.width_tie]
        active_by_layer[l.name] = int(round(gov.out_base * mult))
    rng = np.random.RandomState(7)
    params2 = []
    for spec, p in zip(m.params, params):
        arr = np.array(p).copy()
        lname = spec.name.rsplit(".", 1)[0]
        if spec.name.endswith(".w") and lname in active_by_layer and arr.ndim == 4:
            a = active_by_layer[lname]
            if a < arr.shape[-1]:
                arr[..., a:] += rng.randn(*arr[..., a:].shape).astype(np.float32)
        params2.append(jnp.array(arr))
    logits2 = m.apply(params2, x, bits, w, quant=True)
    np.testing.assert_allclose(np.array(logits1), np.array(logits2), rtol=1e-5,
                               atol=1e-6)


def test_bits_change_output():
    m = registry.resnet20()
    params = init_params(m)
    x, _ = batch(m)
    w = base_widths(m)
    l2 = m.apply(params, x, jnp.full((m.num_layers,), 2.0), w, quant=True)
    l8 = m.apply(params, x, jnp.full((m.num_layers,), 8.0), w, quant=True)
    assert float(jnp.max(jnp.abs(l2 - l8))) > 1e-3


def test_train_step_reduces_loss():
    m = registry.resnet20()
    n = len(m.params)
    params = init_params(m)
    x, y = batch(m, bs=32)
    bits = jnp.full((m.num_layers,), 8.0)
    w = base_widths(m)
    ts = jax.jit(T.build_train_step(m))
    zeros = [jnp.zeros_like(p) for p in params]
    args = params + zeros + zeros + [jnp.array(0.0), x, y, bits, w,
                                     jnp.array(3e-3), jnp.array(1e-4)]
    out = ts(*args)
    first = float(out[-1])
    for i in range(12):
        out = ts(*out[:3 * n], jnp.array(float(i + 1)), x, y, bits, w,
                 jnp.array(3e-3), jnp.array(1e-4))
    last = float(out[-1])
    assert last < first, (first, last)


def test_eval_batch_counts():
    m = registry.resnet20()
    params = init_params(m)
    x, y = batch(m, bs=8)
    ev = jax.jit(T.build_eval_batch(m))
    correct, loss = ev(*(params + [x, y, jnp.full((m.num_layers,), 8.0),
                                   base_widths(m)]))
    assert 0.0 <= float(correct) <= 8.0
    assert float(loss) > 0.0


def test_hessian_trace_shape_and_repeatability():
    m = registry.resnet20()
    params = init_params(m)
    x, y = batch(m, bs=16)
    hs = jax.jit(T.build_hessian_trace(m))
    w = base_widths(m)
    out1 = hs(*(params + [x, y, w, jnp.array(0, jnp.int32)]))[0]
    out2 = hs(*(params + [x, y, w, jnp.array(0, jnp.int32)]))[0]
    out3 = hs(*(params + [x, y, w, jnp.array(1, jnp.int32)]))[0]
    assert out1.shape == (m.num_layers,)
    np.testing.assert_allclose(np.array(out1), np.array(out2))
    assert float(jnp.max(jnp.abs(out1 - out3))) > 0.0  # seed matters


def test_layer_meta_consistency():
    for name in SMALL:
        m = registry.BUILDERS[name]()
        for l in m.layers:
            assert l.cmax_out >= l.out_base
            assert 0 <= l.width_tie < m.num_layers
            assert 0 <= l.bits_tie < m.num_layers
            # a width governor must govern itself
            tie = m.layers[l.width_tie]
            assert tie.width_tie == tie.index, (name, l.name)
            if l.kind != "fc":
                assert l.cmax_out == cmax_of(l.out_base)
