"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis-style sweep (seeded, exhaustive over the cross-product) of shapes,
bit-widths and value ranges; `assert_allclose` against `ref.py`. This is the
contract that makes the STE backward pass (which recomputes quantized operands
with the ref formulas) exact w.r.t. the Pallas forward.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import fake_quant as fq
from compile.kernels import qmatmul as qmm
from compile.kernels import ref

SHAPES = [(1,), (7,), (16,), (3, 5), (8, 8), (4, 3, 2), (2, 3, 3, 4), (128,)]
BITS = [2.0, 3.0, 4.0, 6.0, 8.0, 16.0]
SCALES = [0.01, 1.0, 37.5]


def rand(shape, seed, scale=1.0):
    rng = np.random.RandomState(seed)
    return jnp.array(rng.randn(*shape).astype(np.float32) * scale)


class TestFakeQuant:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("bits", BITS)
    def test_matches_ref(self, shape, bits):
        x = rand(shape, seed=hash((shape, bits)) % 2**31)
        b = jnp.array([bits], dtype=jnp.float32)
        np.testing.assert_allclose(fq.fake_quant(x, b), ref.fake_quant_ref(x, b),
                                   rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("scale", SCALES)
    def test_value_ranges(self, scale):
        x = rand((16, 16), seed=3, scale=scale)
        b = jnp.array([4.0], dtype=jnp.float32)
        np.testing.assert_allclose(fq.fake_quant(x, b), ref.fake_quant_ref(x, b),
                                   rtol=1e-6, atol=1e-6)

    def test_zero_tensor(self):
        x = jnp.zeros((8, 8), jnp.float32)
        b = jnp.array([4.0], dtype=jnp.float32)
        out = fq.fake_quant(x, b)
        np.testing.assert_array_equal(np.array(out), np.zeros((8, 8), np.float32))

    def test_level_count(self):
        """b-bit symmetric quantization produces at most 2^b - 1 distinct values."""
        for bits in [2.0, 3.0, 4.0]:
            x = rand((4096,), seed=11)
            out = np.array(fq.fake_quant(x, jnp.array([bits], jnp.float32)))
            assert len(np.unique(out)) <= 2 ** int(bits) - 1

    def test_idempotent(self):
        """Quantizing an already-quantized tensor is a fixed point."""
        x = rand((64,), seed=5)
        b = jnp.array([3.0], dtype=jnp.float32)
        once = fq.fake_quant(x, b)
        twice = fq.fake_quant(once, b)
        np.testing.assert_allclose(np.array(once), np.array(twice),
                                   rtol=1e-6, atol=1e-7)

    def test_high_bits_near_identity(self):
        x = rand((32, 32), seed=7)
        out = fq.fake_quant(x, jnp.array([16.0], jnp.float32))
        np.testing.assert_allclose(np.array(out), np.array(x), rtol=1e-3,
                                   atol=1e-3)

    def test_monotone_error_in_bits(self):
        """Quantization error decreases (weakly) as bits increase."""
        x = rand((1024,), seed=9)
        errs = []
        for bits in [2.0, 3.0, 4.0, 6.0, 8.0]:
            out = fq.fake_quant(x, jnp.array([bits], jnp.float32))
            errs.append(float(jnp.mean((out - x) ** 2)))
        assert all(a >= b for a, b in zip(errs, errs[1:])), errs


class TestQMatmul:
    @pytest.mark.parametrize("mkn", [(4, 4, 4), (16, 12, 8), (32, 7, 10),
                                     (256, 16, 128), (33, 5, 3), (512, 24, 20)])
    @pytest.mark.parametrize("bits", [(2.0, 2.0), (4.0, 4.0), (8.0, 3.0),
                                      (16.0, 16.0)])
    def test_matches_ref(self, mkn, bits):
        m, k, n = mkn
        bx, bw = bits
        x = rand((m, k), seed=m * 1000 + k)
        w = rand((k, n), seed=n * 77 + k)
        bxa = jnp.array(bx, jnp.float32)
        bwa = jnp.array(bw, jnp.float32)
        sx = ref.quant_scale(x, bxa)
        sw = ref.quant_scale(w, bwa)
        got = qmm.qmatmul(x, w, sx, sw, bxa, bwa)
        want = ref.qmatmul_ref(x, w, sx, sw, bxa, bwa)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-5,
                                   atol=1e-5)

    def test_tiling_invariance(self):
        """Same numerics regardless of tile decomposition (scales are
        per-tensor, computed outside the kernel)."""
        m, k, n = 64, 16, 32
        x = rand((m, k), seed=1)
        w = rand((k, n), seed=2)
        b = jnp.array(4.0, jnp.float32)
        sx, sw = ref.quant_scale(x, b), ref.quant_scale(w, b)
        full = qmm.qmatmul(x, w, sx, sw, b, b)
        old_m, old_n = qmm.MAX_TILE_M, qmm.MAX_TILE_N
        try:
            qmm.MAX_TILE_M, qmm.MAX_TILE_N = 16, 8
            tiled = qmm.qmatmul(x, w, sx, sw, b, b)
        finally:
            qmm.MAX_TILE_M, qmm.MAX_TILE_N = old_m, old_n
        np.testing.assert_allclose(np.array(full), np.array(tiled), rtol=1e-5,
                                   atol=1e-6)

    def test_vmem_estimate_positive(self):
        assert qmm.qmatmul_vmem_bytes(256, 64, 128) > 0
        assert qmm.qmatmul_mxu_passes(256, 256, 256) == 8


class TestSTE:
    def test_fake_quant_grad_identity(self):
        from compile.qat import fake_quant_ste
        x = rand((8, 8), seed=21)
        b = jnp.array([4.0], jnp.float32)
        g = jax.grad(lambda v: jnp.sum(fake_quant_ste(v, b) * 3.0))(x)
        np.testing.assert_allclose(np.array(g), np.full((8, 8), 3.0, np.float32),
                                   rtol=1e-6)

    def test_qmatmul_grad_matches_ste_composition(self):
        """grad of qmatmul_ste == grad of fq(x)@fq(w) built from fake_quant_ste."""
        from compile.qat import fake_quant_ste, qmatmul_ste
        x = rand((8, 4), seed=31)
        w = rand((4, 6), seed=32)
        b = jnp.array(3.0, jnp.float32)
        b1 = jnp.reshape(b, (1,))

        def f_fused(x, w):
            return jnp.sum(qmatmul_ste(x, w, b, b) ** 2)

        def f_composed(x, w):
            return jnp.sum((fake_quant_ste(x, b1) @ fake_quant_ste(w, b1)) ** 2)

        gx1, gw1 = jax.grad(f_fused, argnums=(0, 1))(x, w)
        gx2, gw2 = jax.grad(f_composed, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.array(gx1), np.array(gx2), rtol=1e-4,
                                   atol=1e-5)
        np.testing.assert_allclose(np.array(gw1), np.array(gw2), rtol=1e-4,
                                   atol=1e-5)
